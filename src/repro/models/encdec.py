"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, T_frames, d].  Encoder = bidirectional
pre-LN transformer with sinusoidal positions; decoder = causal pre-LN
transformer with learned positions, cross-attending to the encoder output.
Embeddings are tied to the LM head (whisper convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import ParamSpec, SpecTree
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.transformer import _maybe_remat, _stack, _write_prefill


def _enc_layer_specs(cfg: ModelConfig) -> dict:
    specs: dict = {}
    specs.update({("attn",) + p: s for p, s in attn.attention_spec(cfg).items()})
    specs.update({("attn_norm",) + p: s for p, s in L.layernorm_spec(cfg.d_model).items()})
    specs.update({("ffn_norm",) + p: s for p, s in L.layernorm_spec(cfg.d_model).items()})
    specs.update({("ffn",) + p: s for p, s in L.gelu_ffn_spec(cfg.d_model, cfg.d_ff).items()})
    return specs


def _dec_layer_specs(cfg: ModelConfig) -> dict:
    specs = _enc_layer_specs(cfg)
    specs.update({("xattn",) + p: s for p, s in attn.attention_spec(cfg, cross=True).items()})
    specs.update({("xattn_norm",) + p: s for p, s in L.layernorm_spec(cfg.d_model).items()})
    return specs


def param_specs(cfg: ModelConfig) -> SpecTree:
    specs: SpecTree = {}
    specs.update({("embed",) + p: s for p, s in L.embed_spec(cfg.vocab_size, cfg.d_model).items()})
    specs[("pos_embed",)] = ParamSpec((cfg.max_position, cfg.d_model), ("seq", "embed"), init="normal")
    specs.update(_stack(_enc_layer_specs(cfg), cfg.encoder_layers, "enc_layers"))
    specs.update(_stack(_dec_layer_specs(cfg), cfg.num_layers, "dec_layers"))
    specs.update({("enc_norm",) + p: s for p, s in L.layernorm_spec(cfg.d_model).items()})
    specs.update({("final_norm",) + p: s for p, s in L.layernorm_spec(cfg.d_model).items()})
    return specs  # tied embeddings: no separate head


def _sinusoidal(t: int, d: int) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params, frames, *, cfg: ModelConfig, remat=False):
    """frames: [B, T, d] (stub frontend output) -> [B, T, d]."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def layer(lp, x):
        from repro.dist.sharding import shard_activation
        x = shard_activation(x, ("batch", None, None))
        h = L.layernorm(lp["attn_norm"], x, cfg.norm_eps)
        a, _ = attn.self_attention(lp["attn"], h, cfg=cfg, causal=False)
        x = x + a
        h = L.layernorm(lp["ffn_norm"], x, cfg.norm_eps)
        return x + L.gelu_ffn(lp["ffn"], h)

    body = _maybe_remat(layer, cfg, remat)
    x, _ = jax.lax.scan(lambda x, lp: (body(lp, x), None), x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_layer_seq(lp, x, enc_out, *, cfg: ModelConfig):
    from repro.dist.sharding import shard_activation
    x = shard_activation(x, ("batch", "seq_act", None))
    h = L.layernorm(lp["attn_norm"], x, cfg.norm_eps)
    a, kv = attn.self_attention(lp["attn"], h, cfg=cfg, causal=True)
    x = x + a
    h = L.layernorm(lp["xattn_norm"], x, cfg.norm_eps)
    x = x + attn.cross_attention(lp["xattn"], h, enc_out, cfg=cfg)
    h = L.layernorm(lp["ffn_norm"], x, cfg.norm_eps)
    return x + L.gelu_ffn(lp["ffn"], h), kv


def _decode_logits(params, x, cfg):
    x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x, tied=True)


def forward(params, tokens, *, cfg: ModelConfig, extra=None, remat=False):
    """Teacher-forced decoder pass. tokens [B,S]; extra['audio_frames'] [B,T,d]."""
    enc_out = encode(params, extra["audio_frames"], cfg=cfg, remat=remat)
    s = tokens.shape[1]
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x = x + params["pos_embed"][:s].astype(x.dtype)
    body = _maybe_remat(functools.partial(_dec_layer_seq, cfg=cfg), cfg, remat)
    x, _ = jax.lax.scan(lambda x, lp: (body(lp, x, enc_out)[0], None), x, params["dec_layers"])
    return _decode_logits(params, x, cfg), {}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> SpecTree:
    hk, hd, n = cfg.num_kv_heads, cfg.hd, cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "qkv")
    x_axes = ("layers", "batch", "frames", "kv_heads", "qkv")
    return {
        ("self", "k"): ParamSpec((n, batch, max_seq, hk, hd), kv_axes, dtype=dt, init="zeros"),
        ("self", "v"): ParamSpec((n, batch, max_seq, hk, hd), kv_axes, dtype=dt, init="zeros"),
        ("cross", "k"): ParamSpec((n, batch, cfg.num_audio_frames, hk, hd), x_axes, dtype=dt, init="zeros"),
        ("cross", "v"): ParamSpec((n, batch, cfg.num_audio_frames, hk, hd), x_axes, dtype=dt, init="zeros"),
    }


def prefill(params, tokens, cache, *, cfg: ModelConfig, extra=None, last_only=False):
    enc_out = encode(params, extra["audio_frames"], cfg=cfg)
    s = tokens.shape[1]
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x = x + params["pos_embed"][:s].astype(x.dtype)

    def body(x, lp):
        x, kv = _dec_layer_seq(lp, x, enc_out, cfg=cfg)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, params["dec_layers"])

    def xkv(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
        return k, v

    xk, xv = jax.vmap(xkv)(params["dec_layers"])
    new_cache = {
        "self": {"k": _write_prefill(cache["self"]["k"], ks),
                 "v": _write_prefill(cache["self"]["v"], vs)},
        "cross": {"k": xk.astype(cache["cross"]["k"].dtype),
                  "v": xv.astype(cache["cross"]["v"].dtype)},
    }
    if last_only:
        x = x[:, -1:]
    return _decode_logits(params, x, cfg), new_cache


def decode_step(params, tokens, cache, cache_len, *, cfg: ModelConfig, extra=None):
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (tokens.shape[0],))
    x = x + params["pos_embed"][lens][:, None].astype(x.dtype)

    def body(x, inp):
        lp, kc, vc, xk, xv = inp
        h = L.layernorm(lp["attn_norm"], x, cfg.norm_eps)
        a, kc, vc = attn.decode_self_attention(lp["attn"], h, kc, vc, cache_len, cfg=cfg)
        x = x + a
        h = L.layernorm(lp["xattn_norm"], x, cfg.norm_eps)
        x = x + attn.decode_cross_attention(lp["xattn"], h, xk, xv, cfg=cfg)
        h = L.layernorm(lp["ffn_norm"], x, cfg.norm_eps)
        x = x + L.gelu_ffn(lp["ffn"], h)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"]["k"], cache["self"]["v"],
                  cache["cross"]["k"], cache["cross"]["v"]))
    new_cache = {"self": {"k": ks, "v": vs}, "cross": cache["cross"]}
    return _decode_logits(params, x, cfg), new_cache
