"""Mamba2-style state-space layer (SSD) with chunked parallel scan.

The core primitive ``ssd_chunked`` implements the scalar-decay SSD recurrence

    h_t = a_t * h_{t-1} + B_t (x_t)^T        (state [H, P, N], a_t scalar/head)
    y_t = C_t^T h_t

as (intra-chunk quadratic attention-like pass) + (inter-chunk state scan).
We scan over chunks with the running state as carry so the [H, Q, Q] decay
matrices exist for one chunk at a time (memory-safe at 500k sequence length).
The same primitive powers the xLSTM mLSTM block (see xlstm.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import ParamSpec
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Core SSD primitive
# ---------------------------------------------------------------------------


def ssd_chunked(x, log_a, B, C, *, chunk: int, h0=None, normalize: bool = False):
    """Chunked scalar-decay SSD.

    x:     [b, L, H, P]   (inputs, already gated/scaled by dt etc.)
    log_a: [b, L, H]      (log decay per head, <= 0)
    B, C:  [b, L, G, N]   (input/output projections, G groups broadcast to H)
    h0:    optional initial state [b, H, P, N]

    Returns (y [b, L, H, P], h_final [b, H, P, N]).
    If ``normalize``, y is divided by the matching scalar recurrence of a
    normalizer n_t = a_t n_{t-1} + B_t (mLSTM denominator).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(chunk, L)
    if L % Q:  # pad with identity steps (a=1, zero input) — state passes through
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, h = ssd_chunked(x, log_a, B, C, chunk=Q, h0=h0, normalize=normalize)
        return y[:, :L], h
    nc = L // Q
    hpg = H // G
    f32 = jnp.float32

    def to_chunks(t):
        return t.reshape((b, nc, Q) + t.shape[2:])

    xc = to_chunks(x)
    lac = to_chunks(log_a).astype(f32)
    Bc, Cc = to_chunks(B), to_chunks(C)
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), f32)

    # move chunk axis to front for scan
    xc = jnp.moveaxis(xc, 1, 0)
    lac = jnp.moveaxis(lac, 1, 0)
    Bc = jnp.moveaxis(Bc, 1, 0)
    Cc = jnp.moveaxis(Cc, 1, 0)

    mask = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h_prev, inp):
        xq, laq, Bq, Cq = inp            # [b,Q,H,P], [b,Q,H], [b,Q,G,N]
        cum = jnp.cumsum(laq, axis=1)    # [b,Q,H]
        # group -> heads broadcast
        Bh = jnp.repeat(Bq, hpg, axis=2) if G != H else Bq   # [b,Q,H,N]
        Ch = jnp.repeat(Cq, hpg, axis=2) if G != H else Cq

        # intra-chunk: scores[t,s] = C_t . B_s * exp(cum_t - cum_s), s <= t
        scores = jnp.einsum("bqhn,bshn->bhqs", Ch.astype(f32), Bh.astype(f32))
        decay = jnp.exp(cum[:, :, None, :].transpose(0, 3, 1, 2)
                        - cum[:, None, :, :].transpose(0, 3, 1, 2))  # [b,H,Q,Q]
        w = jnp.where(mask[None, None], scores * decay, 0.0)
        y_intra = jnp.einsum("bhqs,bshp->bqhp", w, xq.astype(f32))

        # inter-chunk contribution from carried state
        in_decay = jnp.exp(cum)          # [b,Q,H]
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Ch.astype(f32) * in_decay[..., None], h_prev)

        # new chunk state
        out_decay = jnp.exp(cum[:, -1:, :] - cum)  # decay from s to end of chunk
        S = jnp.einsum("bqhn,bqhp->bhpn", Bh.astype(f32) * out_decay[..., None], xq.astype(f32))
        a_chunk = jnp.exp(cum[:, -1, :])           # [b,H]
        h_new = a_chunk[:, :, None, None] * h_prev + S
        return h_new, (y_intra + y_inter).astype(x.dtype)

    with jax.named_scope("ssd_core"):
        h_final, ys = jax.lax.scan(body, h0, (xc, lac, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, L, H, P)

    if normalize:
        ones = jnp.ones_like(x[..., :1])
        n, _ = ssd_chunked(ones, log_a, B, C, chunk=chunk, normalize=False)
        y = (y.astype(f32) / jnp.maximum(jnp.abs(n.astype(f32)), 1.0)).astype(x.dtype)
    return y, h_final.astype(f32)


def ssd_decode_step(h, x, log_a, B, C):
    """Single-token SSD update. h:[b,H,P,N] x:[b,H,P] log_a:[b,H] B,C:[b,G,N]."""
    G, H = B.shape[1], x.shape[1]
    hpg = H // G
    Bh = jnp.repeat(B, hpg, axis=1) if G != H else B  # [b,H,N]
    Ch = jnp.repeat(C, hpg, axis=1) if G != H else C
    a = jnp.exp(log_a.astype(jnp.float32))[:, :, None, None]
    h = a * h + jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32), x.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h)
    return h, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // 64  # head size P=64, mamba2 default
    N, G, cw = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    conv_dim = d_in + 2 * G * N
    return {
        ("in_proj",): ParamSpec((d, 2 * d_in + 2 * G * N + H), ("embed_in", "ssm_in"), init="scaled"),
        ("conv_w",): ParamSpec((cw, conv_dim), ("conv", "ssm_in"), init="scaled"),
        ("conv_b",): ParamSpec((conv_dim,), ("ssm_in",), init="zeros", dtype=jnp.float32),
        ("A_log",): ParamSpec((H,), ("heads",), init="zeros", dtype=jnp.float32),
        ("dt_bias",): ParamSpec((H,), ("heads",), init="zeros", dtype=jnp.float32),
        ("D",): ParamSpec((H,), ("heads",), init="ones", dtype=jnp.float32),
        ("norm_scale",): ParamSpec((d_in,), ("ssm_inner",), init="ones", dtype=jnp.float32),
        ("out_proj",): ParamSpec((d_in, d), ("ssm_inner", "embed_out"), init="scaled"),
    }


def _mamba2_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // 64
    return d_in, H, 64, cfg.ssm_state, cfg.ssm_groups


def _split_in_proj(cfg, proj):
    d_in, H, P, N, G = _mamba2_dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * G * N], axis=-1)
    return z, xbc, dt


def _gated_norm(scale, y, z, eps):
    """Mamba2's RMSNorm(y * silu(z))."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def mamba2_forward(params, x, *, cfg: ModelConfig, state=None, return_state: bool = False):
    """Full-sequence Mamba2 mixer. x: [b, L, d] -> [b, L, d] (+ optional state)."""
    b, L, d = x.shape
    d_in, H, P, N, G = _mamba2_dims(cfg)
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc, dt = _split_in_proj(cfg, proj)

    # depthwise causal conv over (x, B, C)
    cw = cfg.ssm_conv
    if state is None:
        pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    conv = sum(pad[:, i:i + L] * params["conv_w"][i].astype(x.dtype) for i in range(cw))
    conv = jax.nn.silu((conv + params["conv_b"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    xs, B, C = jnp.split(conv, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(b, L, H, P)
    B = B.reshape(b, L, G, N)
    C = C.reshape(b, L, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [b,L,H]
    log_a = -dt * jnp.exp(params["A_log"])
    x_in = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    y, h_final = ssd_chunked(x_in, log_a, B, C, chunk=cfg.ssm_chunk,
                             h0=state["h"] if state is not None else None)
    y = y + xs * params["D"][None, None, :, None].astype(x.dtype)
    y = _gated_norm(params["norm_scale"], y.reshape(b, L, d_in), z, cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    if return_state:
        new_conv = pad[:, L:] if state is not None else xbc[:, max(L - (cw - 1), 0):]
        if new_conv.shape[1] < cw - 1:  # short sequences: left-pad with zeros
            z0 = jnp.zeros((b, cw - 1 - new_conv.shape[1], new_conv.shape[2]), new_conv.dtype)
            new_conv = jnp.concatenate([z0, new_conv], axis=1)
        return out, {"conv": new_conv, "h": h_final}
    return out


def mamba2_state_specs(cfg: ModelConfig, batch: int) -> dict:
    d_in, H, P, N, G = _mamba2_dims(cfg)
    conv_dim = d_in + 2 * G * N
    return {
        ("conv",): ParamSpec((batch, cfg.ssm_conv - 1, conv_dim), ("batch", None, "ssm_in"),
                             dtype=jnp.dtype(cfg.dtype), init="zeros"),
        ("h",): ParamSpec((batch, H, P, N), ("batch", "heads", None, None),
                          dtype=jnp.float32, init="zeros"),
    }


def mamba2_decode(params, state, x, *, cfg: ModelConfig):
    """Single-token step. x: [b, 1, d]; state: {'conv': [b,cw-1,Cd], 'h': [b,H,P,N]}."""
    b, _, d = x.shape
    d_in, H, P, N, G = _mamba2_dims(cfg)
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"])[:, 0]
    z, xbc, dt = _split_in_proj(cfg, proj)

    hist = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # [b,cw,Cd]
    conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), params["conv_w"])
    conv = jax.nn.silu(conv + params["conv_b"]).astype(x.dtype)
    new_conv = hist[:, 1:]

    xs, B, C = jnp.split(conv, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(b, H, P)
    B = B.reshape(b, G, N)
    C = C.reshape(b, G, N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    log_a = -dtv * jnp.exp(params["A_log"])
    h, y = ssd_decode_step(state["h"], (xs.astype(jnp.float32) * dtv[..., None]).astype(x.dtype), log_a, B, C)
    y = y + xs * params["D"][None, :, None].astype(x.dtype)
    y = _gated_norm(params["norm_scale"], y.reshape(b, 1, d_in), z[:, None, :], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return {"conv": new_conv, "h": h}, out
