"""Unified decoder-only transformer LM (dense / MoE / VLM families).

Parameters are *stacked over layers* and iterated with ``jax.lax.scan`` so the
HLO (and compile time) is O(1) in depth.  Heterogeneous depth patterns are
expressed as *grouped* scans:

  * MoE with ``moe_interval=k``: scan over groups of (k-1 dense + 1 MoE) layers
  * VLM with ``cross_attn_interval=k``: scan over groups of (1 gated
    cross-attention block + k self-attention layers)

Three entry points share the layer body:
  forward      (train / scoring: full sequence -> logits, aux losses)
  prefill      (full sequence -> logits + filled KV cache)
  decode_step  (1 token + cache -> logits + updated cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import ParamSpec, SpecTree
from repro.configs.base import ModelConfig
from repro.dist.sharding import shard_activation
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _stack(specs: dict, n: int, prefix: str) -> SpecTree:
    out = {}
    for path, s in specs.items():
        out[(prefix,) + path] = ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                                          dtype=s.dtype, init=s.init, init_scale=s.init_scale)
    return out


def _decoder_layer_specs(cfg: ModelConfig, *, use_moe: bool) -> dict:
    specs: dict = {}
    for p, s in attn.attention_spec(cfg).items():
        specs[("attn",) + p] = s
    for p, s in L.rmsnorm_spec(cfg.d_model).items():
        specs[("attn_norm",) + p] = s
        specs[("ffn_norm",) + p] = s
    if use_moe:
        for p, s in moe_mod.moe_spec(cfg).items():
            specs[("moe",) + p] = s
        if cfg.moe_shared_expert:
            for p, s in L.swiglu_spec(cfg.d_model, cfg.d_ff).items():
                specs[("shared",) + p] = s
    else:
        for p, s in L.swiglu_spec(cfg.d_model, cfg.d_ff).items():
            specs[("ffn",) + p] = s
    return specs


def _cross_layer_specs(cfg: ModelConfig) -> dict:
    specs: dict = {}
    for p, s in attn.attention_spec(cfg, cross=True).items():
        specs[("xattn",) + p] = s
    for p, s in L.rmsnorm_spec(cfg.d_model).items():
        specs[("xattn_norm",) + p] = s
        specs[("xffn_norm",) + p] = s
    for p, s in L.swiglu_spec(cfg.d_model, cfg.d_ff).items():
        specs[("xffn",) + p] = s
    specs[("attn_gate",)] = ParamSpec((), (), init="zeros", dtype=jnp.float32)
    specs[("ffn_gate",)] = ParamSpec((), (), init="zeros", dtype=jnp.float32)
    return specs


def layer_layout(cfg: ModelConfig) -> dict:
    """How the depth dimension is organized into scanned stacks."""
    if cfg.family == "vlm" and cfg.cross_attn_interval:
        n_groups = cfg.num_layers // cfg.cross_attn_interval
        return {"kind": "vlm", "groups": n_groups, "per_group": cfg.cross_attn_interval,
                "dense": cfg.num_layers, "cross": n_groups}
    if cfg.is_moe and cfg.moe_interval > 1:
        n_groups = cfg.num_layers // cfg.moe_interval
        return {"kind": "moe_interleave", "groups": n_groups,
                "dense_per_group": cfg.moe_interval - 1,
                "dense": n_groups * (cfg.moe_interval - 1), "moe": n_groups}
    if cfg.is_moe:
        return {"kind": "moe", "moe": cfg.num_layers, "dense": 0}
    return {"kind": "dense", "dense": cfg.num_layers}


def param_specs(cfg: ModelConfig) -> SpecTree:
    lay = layer_layout(cfg)
    specs: SpecTree = {}
    specs.update({("embed",) + p: s for p, s in L.embed_spec(cfg.vocab_size, cfg.d_model).items()})
    if lay["kind"] == "moe":
        specs.update(_stack(_decoder_layer_specs(cfg, use_moe=True), lay["moe"], "layers"))
    else:
        if lay.get("dense"):
            specs.update(_stack(_decoder_layer_specs(cfg, use_moe=False), lay["dense"], "layers"))
        if lay["kind"] == "moe_interleave":
            specs.update(_stack(_decoder_layer_specs(cfg, use_moe=True), lay["moe"], "moe_layers"))
        if lay["kind"] == "vlm":
            specs.update(_stack(_cross_layer_specs(cfg), lay["cross"], "cross_layers"))
    specs.update({("final_norm",) + p: s for p, s in L.rmsnorm_spec(cfg.d_model).items()})
    specs.update({("out",) + p: s for p, s in L.unembed_spec(cfg.vocab_size, cfg.d_model, tied=cfg.tie_embeddings).items()})
    return specs


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _decoder_layer_seq(lp, x, *, cfg: ModelConfig, use_moe: bool):
    """Full-sequence decoder layer. Returns (x, (k, v), aux)."""
    x = shard_activation(x, ("batch", "seq_act", "embed_act"))
    h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    a, kv = attn.self_attention(lp["attn"], h, cfg=cfg)
    x = x + a
    h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
    aux = {}
    if use_moe:
        f, aux = moe_mod.moe_ffn(lp["moe"], h, cfg=cfg)
        if cfg.moe_shared_expert:
            f = f + L.swiglu(lp["shared"], h)
    else:
        f = L.swiglu(lp["ffn"], h)
    return x + f, kv, aux


def _decoder_layer_decode(lp, x, k_cache, v_cache, cache_len, *, cfg: ModelConfig, use_moe: bool):
    h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    a, k_cache, v_cache = attn.decode_self_attention(lp["attn"], h, k_cache, v_cache, cache_len, cfg=cfg)
    x = x + a
    h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
    if use_moe:
        f, _ = moe_mod.moe_ffn(lp["moe"], h, cfg=cfg)
        if cfg.moe_shared_expert:
            f = f + L.swiglu(lp["shared"], h)
    else:
        f = L.swiglu(lp["ffn"], h)
    return x + f, k_cache, v_cache


def _cross_block_seq(cp, x, mem, *, cfg: ModelConfig):
    h = L.rmsnorm(cp["xattn_norm"], x, cfg.norm_eps)
    a = attn.cross_attention(cp["xattn"], h, mem, cfg=cfg)
    x = x + jnp.tanh(cp["attn_gate"]).astype(x.dtype) * a
    h = L.rmsnorm(cp["xffn_norm"], x, cfg.norm_eps)
    f = L.swiglu(cp["xffn"], h)
    return x + jnp.tanh(cp["ffn_gate"]).astype(x.dtype) * f


def _cross_block_decode(cp, x, k_mem, v_mem, *, cfg: ModelConfig):
    h = L.rmsnorm(cp["xattn_norm"], x, cfg.norm_eps)
    a = attn.decode_cross_attention(cp["xattn"], h, k_mem, v_mem, cfg=cfg)
    x = x + jnp.tanh(cp["attn_gate"]).astype(x.dtype) * a
    h = L.rmsnorm(cp["xffn_norm"], x, cfg.norm_eps)
    f = L.swiglu(cp["xffn"], h)
    return x + jnp.tanh(cp["ffn_gate"]).astype(x.dtype) * f


def _maybe_remat(fn, cfg: ModelConfig, enable: bool):
    if enable and cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _add_aux(acc, aux):
    return {k: acc.get(k, 0.0) + v for k, v in aux.items()} if aux else acc


def _group_tree(tree, n_groups: int):
    return jax.tree.map(lambda a: a.reshape((n_groups, a.shape[0] // n_groups) + a.shape[1:]), tree)


# ---------------------------------------------------------------------------
# Full-sequence pass (train / prefill)
# ---------------------------------------------------------------------------


def _run_layers_seq(params, x, *, cfg: ModelConfig, extra, remat: bool, collect_kv: bool):
    """Returns (x, kv_stacks: dict[str, (k, v)] or None, aux)."""
    lay = layer_layout(cfg)
    aux0 = {"moe_lb": 0.0, "moe_z": 0.0} if cfg.is_moe else {}
    kv_out: dict[str, Any] = {}

    if lay["kind"] in ("dense", "moe"):
        use_moe = lay["kind"] == "moe"
        body_fn = _maybe_remat(
            functools.partial(_decoder_layer_seq, cfg=cfg, use_moe=use_moe), cfg, remat)

        def body(carry, lp):
            x, aux = carry
            x, kv, a = body_fn(lp, x)
            return (x, _add_aux(aux, a)), kv if collect_kv else None

        (x, aux), kvs = jax.lax.scan(body, (x, aux0), params["layers"])
        if collect_kv:
            kv_out["self"] = kvs

    elif lay["kind"] == "moe_interleave":
        dense_fn = _maybe_remat(functools.partial(_decoder_layer_seq, cfg=cfg, use_moe=False), cfg, remat)
        moe_fn = _maybe_remat(functools.partial(_decoder_layer_seq, cfg=cfg, use_moe=True), cfg, remat)
        dense_groups = _group_tree(params["layers"], lay["groups"])

        def group(carry, gp):
            x, aux = carry
            dense_p, moe_p = gp

            def inner(c, lp):
                x, aux = c
                x, kv, a = dense_fn(lp, x)
                return (x, _add_aux(aux, a)), kv if collect_kv else None

            (x, aux), d_kvs = jax.lax.scan(inner, (x, aux), dense_p)
            x, m_kv, a = moe_fn(moe_p, x)
            return (x, _add_aux(aux, a)), ((d_kvs, m_kv) if collect_kv else None)

        (x, aux), kvs = jax.lax.scan(group, (x, aux0), (dense_groups, params["moe_layers"]))
        if collect_kv:
            kv_out["dense"], kv_out["moe"] = kvs

    else:  # vlm
        mem = extra["image_embeds"]
        self_fn = _maybe_remat(functools.partial(_decoder_layer_seq, cfg=cfg, use_moe=False), cfg, remat)
        cross_fn = _maybe_remat(functools.partial(_cross_block_seq, cfg=cfg), cfg, remat)
        groups = _group_tree(params["layers"], lay["groups"])

        def group(carry, gp):
            x, aux = carry
            cross_p, self_p = gp
            x = cross_fn(cross_p, x, mem)

            def inner(c, lp):
                x, aux = c
                x, kv, a = self_fn(lp, x)
                return (x, _add_aux(aux, a)), kv if collect_kv else None

            (x, aux), kvs = jax.lax.scan(inner, (x, aux), self_p)
            return (x, aux), kvs

        (x, aux), kvs = jax.lax.scan(group, (x, aux0), (params["cross_layers"], groups))
        if collect_kv:
            kv_out["self"] = jax.tree.map(
                lambda a: a.reshape((lay["dense"],) + a.shape[2:]), kvs)
            # precompute cross K/V once per cross layer for decode
            def xkv(cp):
                k = jnp.einsum("bsd,dhk->bshk", mem, cp["xattn"]["wk"])
                v = jnp.einsum("bsd,dhk->bshk", mem, cp["xattn"]["wv"])
                return k, v
            kv_out["cross"] = jax.vmap(xkv)(params["cross_layers"])
        aux = dict(aux)

    return x, (kv_out if collect_kv else None), aux


def forward(params, tokens, *, cfg: ModelConfig, extra=None, remat=False):
    """tokens [B,S] -> (logits [B,S,V] f32, aux dict)."""
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x, _, aux = _run_layers_seq(params, x, cfg=cfg, extra=extra, remat=remat, collect_kv=False)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, aux


# ---------------------------------------------------------------------------
# KV cache structure + prefill / decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> SpecTree:
    lay = layer_layout(cfg)
    hk, hd = cfg.num_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "qkv")

    def kv(n_layers, seq):
        return ParamSpec((n_layers, batch, seq, hk, hd), kv_axes, dtype=dt, init="zeros")

    specs: SpecTree = {}
    if lay["kind"] in ("dense", "moe"):
        n = lay.get("dense") or lay.get("moe")
        specs[("self", "k")] = kv(n, max_seq)
        specs[("self", "v")] = kv(n, max_seq)
    elif lay["kind"] == "moe_interleave":
        specs[("dense", "k")] = kv(lay["groups"] * lay["dense_per_group"], max_seq)
        specs[("dense", "v")] = kv(lay["groups"] * lay["dense_per_group"], max_seq)
        specs[("moe", "k")] = kv(lay["groups"], max_seq)
        specs[("moe", "v")] = kv(lay["groups"], max_seq)
    else:  # vlm
        specs[("self", "k")] = kv(lay["dense"], max_seq)
        specs[("self", "v")] = kv(lay["dense"], max_seq)
        specs[("cross", "k")] = kv(lay["cross"], cfg.num_image_tokens)
        specs[("cross", "v")] = kv(lay["cross"], cfg.num_image_tokens)
    return specs


def _write_prefill(cache_buf, kv_new):
    """Place freshly computed [L,B,S,hk,hd] K/V at the head of a [L,B,Smax,...] buffer."""
    return jax.lax.dynamic_update_slice_in_dim(cache_buf, kv_new.astype(cache_buf.dtype), 0, axis=2)


def prefill(params, tokens, cache, *, cfg: ModelConfig, extra=None, last_only=False):
    """tokens [B,S] + zeroed cache -> (logits, filled cache).

    ``last_only`` computes the unembedding for the final position only (the
    serving path — avoids materializing a [B,S,V] logits tensor at 32k)."""
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x, kvs, _ = _run_layers_seq(params, x, cfg=cfg, extra=extra, remat=False, collect_kv=True)
    lay = layer_layout(cfg)
    new_cache = dict(cache)
    if lay["kind"] == "moe_interleave":
        d_kvs, m_kv = kvs["dense"], kvs["moe"]
        dk = d_kvs[0].reshape((-1,) + d_kvs[0].shape[2:])
        dv = d_kvs[1].reshape((-1,) + d_kvs[1].shape[2:])
        new_cache["dense"] = {"k": _write_prefill(cache["dense"]["k"], dk),
                              "v": _write_prefill(cache["dense"]["v"], dv)}
        new_cache["moe"] = {"k": _write_prefill(cache["moe"]["k"], m_kv[0]),
                            "v": _write_prefill(cache["moe"]["v"], m_kv[1])}
    else:
        k, v = kvs["self"]
        new_cache["self"] = {"k": _write_prefill(cache["self"]["k"], k),
                             "v": _write_prefill(cache["self"]["v"], v)}
        if lay["kind"] == "vlm":
            xk, xv = kvs["cross"]
            new_cache["cross"] = {"k": xk.astype(cache["cross"]["k"].dtype),
                                  "v": xv.astype(cache["cross"]["v"].dtype)}
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, new_cache


def decode_step(params, tokens, cache, cache_len, *, cfg: ModelConfig, extra=None):
    """tokens [B,1] + cache + cache_len -> (logits [B,1,V], updated cache)."""
    lay = layer_layout(cfg)
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    new_cache = dict(cache)

    if lay["kind"] in ("dense", "moe"):
        use_moe = lay["kind"] == "moe"

        def body(x, inp):
            lp, kc, vc = inp
            x, kc, vc = _decoder_layer_decode(lp, x, kc, vc, cache_len, cfg=cfg, use_moe=use_moe)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["self"]["k"], cache["self"]["v"]))
        new_cache["self"] = {"k": ks, "v": vs}

    elif lay["kind"] == "moe_interleave":
        g = lay["groups"]
        dense_groups = _group_tree(params["layers"], g)
        dkc = _group_tree(cache["dense"]["k"], g)
        dvc = _group_tree(cache["dense"]["v"], g)

        def group(x, inp):
            dense_p, moe_p, dkc, dvc, mkc, mvc = inp

            def inner(x, i):
                lp, kc, vc = i
                x, kc, vc = _decoder_layer_decode(lp, x, kc, vc, cache_len, cfg=cfg, use_moe=False)
                return x, (kc, vc)

            x, (dks, dvs) = jax.lax.scan(inner, x, (dense_p, dkc, dvc))
            x, mks, mvs = _decoder_layer_decode(moe_p, x, mkc, mvc, cache_len, cfg=cfg, use_moe=True)
            return x, (dks, dvs, mks, mvs)

        x, (dks, dvs, mks, mvs) = jax.lax.scan(
            group, x, (dense_groups, params["moe_layers"], dkc, dvc, cache["moe"]["k"], cache["moe"]["v"]))
        new_cache["dense"] = {"k": dks.reshape(cache["dense"]["k"].shape),
                              "v": dvs.reshape(cache["dense"]["v"].shape)}
        new_cache["moe"] = {"k": mks, "v": mvs}

    else:  # vlm
        g = lay["groups"]
        groups = _group_tree(params["layers"], g)
        kc = _group_tree(cache["self"]["k"], g)
        vc = _group_tree(cache["self"]["v"], g)

        def group(x, inp):
            cross_p, self_p, kc, vc, xk, xv = inp
            x = _cross_block_decode(cross_p, x, xk, xv, cfg=cfg)

            def inner(x, i):
                lp, k1, v1 = i
                x, k1, v1 = _decoder_layer_decode(lp, x, k1, v1, cache_len, cfg=cfg, use_moe=False)
                return x, (k1, v1)

            x, (ks, vs) = jax.lax.scan(inner, x, (self_p, kc, vc))
            return x, (ks, vs)

        x, (ks, vs) = jax.lax.scan(
            group, x, (params["cross_layers"], groups, kc, vc, cache["cross"]["k"], cache["cross"]["v"]))
        new_cache["self"] = {"k": ks.reshape(cache["self"]["k"].shape),
                             "v": vs.reshape(cache["self"]["v"].shape)}

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed({**params.get("out", {}), **params["embed"]}, x, tied=cfg.tie_embeddings)
    return logits, new_cache
