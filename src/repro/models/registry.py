"""Family dispatch: one uniform interface over all model families.

    param_specs(cfg)                          -> SpecTree
    forward(cfg, params, batch)               -> (logits, aux)
    cache_specs(cfg, batch, max_seq)          -> SpecTree
    prefill(cfg, params, tokens, cache, ...)  -> (logits, cache)
    decode_step(cfg, params, tokens, cache, cache_len, ...) -> (logits, cache)
"""
from __future__ import annotations

from repro.common import SpecTree, init_params as _init, param_structs, unflatten
from repro.configs.base import ModelConfig

from repro.models import encdec, hybrid, transformer, xlstm_lm

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "audio": encdec,
    "ssm": xlstm_lm,
    "hybrid": hybrid,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def param_specs(cfg: ModelConfig) -> SpecTree:
    return module_for(cfg).param_specs(cfg)


def init_params(cfg: ModelConfig, key) -> dict:
    return _init(param_specs(cfg), key)


def param_structs_tree(cfg: ModelConfig) -> dict:
    return param_structs(param_specs(cfg))


def forward(cfg: ModelConfig, params, tokens, *, extra=None, remat=False):
    return module_for(cfg).forward(params, tokens, cfg=cfg, extra=extra, remat=remat)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> SpecTree:
    return module_for(cfg).cache_specs(cfg, batch, max_seq)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    import jax.numpy as jnp
    specs = cache_specs(cfg, batch, max_seq)
    return unflatten({p: jnp.zeros(s.shape, s.dtype) for p, s in specs.items()})


def cache_structs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return param_structs(cache_specs(cfg, batch, max_seq))


def prefill(cfg: ModelConfig, params, tokens, cache, *, extra=None, last_only=False):
    return module_for(cfg).prefill(params, tokens, cache, cfg=cfg, extra=extra,
                                   last_only=last_only)


def decode_step(cfg: ModelConfig, params, tokens, cache, cache_len, *, extra=None):
    return module_for(cfg).decode_step(params, tokens, cache, cache_len, cfg=cfg, extra=extra)
