"""Mixture-of-Experts FFN: top-k routing with per-row capacity grouping.

Design (TPU-native, GSPMD-friendly):
  * tokens are grouped *per batch row*, so position-in-expert cumsums stay
    device-local under batch sharding (no cross-device prefix ops),
  * dispatch/combine are scatter/gather into a dense [B, E, C, d] buffer —
    expert compute is a single einsum that shards cleanly with E on the
    ``model`` mesh axis (expert parallelism) when E is divisible by it,
    otherwise d_ff takes the ``model`` axis (tensor parallelism inside
    experts; mixtral's 8 experts on a 16-wide axis),
  * dropped tokens (beyond capacity) fall into an overflow slot that is
    sliced away — standard capacity-factor semantics.

Returns an aux dict with load-balance and router-z losses (ST-MoE style).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common import ParamSpec
from repro.configs.base import ModelConfig


def moe_spec(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        ("router",): ParamSpec((d, e), ("embed_in", "experts_in"), init="scaled", dtype=jnp.float32),
        ("w_gate",): ParamSpec((e, d, f), ("experts", "embed_in", "mlp_out"), init="scaled"),
        ("w_up",): ParamSpec((e, d, f), ("experts", "embed_in", "mlp_out"), init="scaled"),
        ("w_down",): ParamSpec((e, f, d), ("experts", "mlp", "embed_out"), init="scaled"),
    }


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = math.ceil(seq_len * cfg.experts_per_token / cfg.num_experts * cfg.capacity_factor)
    return max(int(c), 4)


def moe_ffn(params, x, *, cfg: ModelConfig):
    """x: [B, S, d] -> ([B, S, d], aux_losses dict).

    Under an active mesh (dry-run / cluster runs) dispatch goes through the
    shard_map implementation (moe_sharded.py) — GSPMD's handling of the
    dispatch scatter all-reduces the full dispatch buffer otherwise."""
    with jax.named_scope("moe_ffn"):
        from repro.dist import sharding as shd
        ctx = getattr(shd._ctx, "cfg", None)
        if ctx is not None and "model" in ctx[0].axis_names:
            from repro.models.moe_sharded import moe_ffn_sharded
            return moe_ffn_sharded(params, x, cfg=cfg, mesh=ctx[0])
        return _moe_ffn(params, x, cfg=cfg)


def _moe_ffn(params, x, *, cfg: ModelConfig):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [B,S,k]
    if k > 1:  # renormalize selected gates (mixtral convention)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, choice) within its expert, per batch row.
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)      # [B,S,k,E]
    flat = onehot.reshape(b, s * k, e)                           # choice-major within token
    pos = jnp.cumsum(flat, axis=1) - 1                           # [B,S*k,E]
    pos = jnp.sum(pos * flat, axis=-1).reshape(b, s, k)          # [B,S,k]
    dropped = pos >= cap
    slot = jnp.where(dropped, cap, pos)                          # overflow slot = cap

    # dispatch: buffer[b, e, c, :] = x[b, s, :]
    buf = jnp.zeros((b, e, cap + 1, d), x.dtype)
    bidx = jnp.arange(b)[:, None, None]
    buf = buf.at[bidx, expert_idx, slot].add(
        jnp.broadcast_to(x[:, :, None, :], (b, s, k, d)), mode="drop"
    )
    buf = buf[:, :, :cap]

    # expert FFN (dense einsum; E shards over 'model' -> expert parallelism)
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out_buf = jnp.concatenate([out_buf, jnp.zeros((b, e, 1, d), out_buf.dtype)], axis=2)

    # combine: y[b, s] = sum_k gate * out_buf[b, e_k, slot_k]
    gathered = out_buf[bidx, expert_idx, slot]                   # [B,S,k,d]
    gates = jnp.where(dropped, 0.0, gate_vals).astype(x.dtype)
    y = jnp.einsum("bskd,bsk->bsd", gathered, gates)

    # aux losses
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=(1, 2))  # [B,E]
    mean_probs = jnp.mean(probs, axis=1)                                                    # [B,E]
    lb_loss = e * jnp.mean(jnp.sum(frac_tokens * mean_probs, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_lb": lb_loss * cfg.router_aux_coef, "moe_z": z_loss * 1e-3}
    return y, aux
