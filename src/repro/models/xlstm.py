"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel via the SSD primitive)
and sLSTM (scalar memory with exp gates + stabilizer, sequential scan).

Structure follows arXiv:2405.04517: pre-norm residual mixer blocks; every
``cfg.slstm_every``-th block is an sLSTM, the rest are mLSTM.  Deviation
(recorded in DESIGN.md): the mLSTM input gate uses the sigmoid (log-domain
-softplus) parameterization rather than the unbounded exp gate, which removes
the running max-stabilizer state while keeping the matrix-memory/normalizer
structure intact; sLSTM keeps the faithful exp gates + m stabilizer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import ParamSpec
from repro.configs.base import ModelConfig
from repro.models.ssm import ssd_chunked, ssd_decode_step

EXPAND = 2  # mLSTM internal up-projection factor


def _mlstm_dims(cfg: ModelConfig):
    d_in = EXPAND * cfg.d_model
    H = cfg.num_heads
    P = d_in // H       # value head dim
    N = P               # key/query head dim
    return d_in, H, P, N


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, P, N = _mlstm_dims(cfg)
    cw = cfg.ssm_conv
    return {
        ("in_proj",): ParamSpec((d, 2 * d_in), ("embed_in", "mlp"), init="scaled"),
        ("conv_w",): ParamSpec((cw, d_in), ("conv", "mlp"), init="scaled"),
        ("conv_b",): ParamSpec((d_in,), ("mlp",), init="zeros", dtype=jnp.float32),
        ("wq",): ParamSpec((d_in, H, N), ("mlp_in", "heads", "qkv"), init="scaled"),
        ("wk",): ParamSpec((d_in, H, N), ("mlp_in", "heads", "qkv"), init="scaled"),
        ("wv",): ParamSpec((d_in, H, P), ("mlp_in", "heads", "qkv"), init="scaled"),
        ("w_gates",): ParamSpec((d_in, 2 * H), ("mlp_in", "heads"), init="scaled", dtype=jnp.float32),
        ("b_gates",): ParamSpec((2 * H,), ("heads",), init="zeros", dtype=jnp.float32),
        ("norm_scale",): ParamSpec((d_in,), ("mlp",), init="ones", dtype=jnp.float32),
        ("out_proj",): ParamSpec((d_in, d), ("mlp", "embed_out"), init="scaled"),
    }


def _mlstm_qkv_gates(params, x, *, cfg: ModelConfig, conv_hist=None):
    """Common projection path. x: [b, L, d]. Returns (q,k,v,log_f,log_i,z,new_hist)."""
    b, L, d = x.shape
    d_in, H, P, N = _mlstm_dims(cfg)
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"])
    x_in, z = jnp.split(proj, 2, axis=-1)

    cw = cfg.ssm_conv
    if conv_hist is None:
        hist_full = jnp.pad(x_in, ((0, 0), (cw - 1, 0), (0, 0)))
        new_hist = x_in[:, L - (cw - 1):] if L >= cw - 1 else None
    else:
        hist_full = jnp.concatenate([conv_hist.astype(x_in.dtype), x_in], axis=1)
        new_hist = hist_full[:, -(cw - 1):]
    conv = sum(hist_full[:, i:i + L] * params["conv_w"][i].astype(x.dtype) for i in range(cw))
    conv = jax.nn.silu((conv + params["conv_b"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)

    q = jnp.einsum("ble,ehn->blhn", conv, params["wq"]) * (1.0 / jnp.sqrt(N).astype(x.dtype))
    k = jnp.einsum("ble,ehn->blhn", conv, params["wk"])
    v = jnp.einsum("ble,ehp->blhp", x_in, params["wv"])
    gates = jnp.einsum("ble,eh->blh", x_in.astype(jnp.float32), params["w_gates"]) + params["b_gates"]
    f_pre, i_pre = jnp.split(gates, 2, axis=-1)  # [b,L,H]
    log_f = -jax.nn.softplus(-f_pre)             # log sigmoid(f)
    log_i = -jax.nn.softplus(-i_pre)
    return q, k, v, log_f, log_i, z, new_hist


def mlstm_forward(params, x, *, cfg: ModelConfig, state=None, return_state: bool = False):
    """Full-sequence mLSTM mixer. state: optional dict(C, n, conv)."""
    b, L, d = x.shape
    d_in, H, P, N = _mlstm_dims(cfg)
    conv_hist = state["conv"] if state is not None else None
    q, k, v, log_f, log_i, z, new_hist = _mlstm_qkv_gates(params, x, cfg=cfg, conv_hist=conv_hist)

    # fold input gate into k so the normalizer recurrence sees it too
    k_i = k.astype(jnp.float32) * jnp.exp(log_i)[..., None]
    h0 = state["C"] if state is not None else None
    n0 = state["n"][..., None, :] if state is not None else None  # [b,H,1,N]
    y, C_f = ssd_chunked(v, log_f, k_i.astype(v.dtype), q, chunk=cfg.ssm_chunk, h0=h0)
    ones = jnp.ones(v.shape[:3] + (1,), v.dtype)
    nqt, n_f = ssd_chunked(ones, log_f, k_i.astype(v.dtype), q, chunk=cfg.ssm_chunk, h0=n0)
    y = (y.astype(jnp.float32) / jnp.maximum(jnp.abs(nqt.astype(jnp.float32)), 1.0)).astype(x.dtype)

    y = y.reshape(b, L, d_in)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    if return_state:
        return out, {"C": C_f, "n": n_f[:, :, 0, :], "conv": new_hist}
    return out


def mlstm_state_specs(cfg: ModelConfig, batch: int) -> dict:
    d_in, H, P, N = _mlstm_dims(cfg)
    return {
        ("C",): ParamSpec((batch, H, P, N), ("batch", "heads", None, None), dtype=jnp.float32, init="zeros"),
        ("n",): ParamSpec((batch, H, N), ("batch", "heads", None), dtype=jnp.float32, init="zeros"),
        ("conv",): ParamSpec((batch, cfg.ssm_conv - 1, d_in), ("batch", None, "mlp"),
                             dtype=jnp.dtype(cfg.dtype), init="zeros"),
    }


def mlstm_decode(params, state, x, *, cfg: ModelConfig):
    """Single-token mLSTM step. x: [b, 1, d]."""
    b = x.shape[0]
    d_in, H, P, N = _mlstm_dims(cfg)
    q, k, v, log_f, log_i, z, new_hist = _mlstm_qkv_gates(params, x, cfg=cfg, conv_hist=state["conv"])
    k_i = (k.astype(jnp.float32) * jnp.exp(log_i)[..., None])[:, 0]
    C, y = ssd_decode_step(state["C"], v[:, 0], log_f[:, 0], k_i, q[:, 0].astype(jnp.float32))
    n, nqt = ssd_decode_step(state["n"][..., None, :], jnp.ones((b, H, 1), jnp.float32),
                             log_f[:, 0], k_i, q[:, 0].astype(jnp.float32))
    y = y.astype(jnp.float32) / jnp.maximum(jnp.abs(nqt.astype(jnp.float32)), 1.0)
    y = y.reshape(b, 1, d_in)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return {"C": C, "n": n[:, :, 0, :], "conv": new_hist}, out


# ---------------------------------------------------------------------------
# sLSTM block (sequential; exp gates + stabilizer, block-diagonal recurrence)
# ---------------------------------------------------------------------------


def slstm_spec(cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    return {
        ("w_in",): ParamSpec((d, 4 * d), ("embed_in", "mlp"), init="scaled"),
        ("r",): ParamSpec((H, dh, 4 * dh), ("heads", None, None), init="scaled"),
        ("b",): ParamSpec((4 * d,), ("mlp",), init="zeros", dtype=jnp.float32),
        ("out_proj",): ParamSpec((d, d), ("embed_in", "embed_out"), init="scaled"),
    }


def _slstm_step(params, carry, x_t, *, cfg: ModelConfig):
    """One sLSTM step. carry: (h, c, n, m) each [b, d] f32; x_t: [b, d]."""
    h, c, n, m = carry
    b, d = x_t.shape
    H = cfg.num_heads
    dh = d // H
    pre = jnp.einsum("bd,de->be", x_t.astype(jnp.float32), params["w_in"].astype(jnp.float32))
    rec = jnp.einsum("bhx,hxe->bhe", h.reshape(b, H, dh), params["r"].astype(jnp.float32))
    pre = pre + rec.reshape(b, 4 * d) + params["b"]
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    log_f = -jax.nn.softplus(-f_pre)             # sigmoid forget (stable branch)
    m_new = jnp.maximum(log_f + m, i_pre)        # stabilizer
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_pre)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_forward(params, x, *, cfg: ModelConfig, state=None, return_state: bool = False):
    b, L, d = x.shape
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        carry = (z, z, z, z - 30.0)
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])

    def body(carry, x_t):
        new = _slstm_step(params, carry, x_t, cfg=cfg)
        return new, new[0]

    carry, hs = jax.lax.scan(body, carry, x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    out = jnp.einsum("bld,de->ble", y, params["out_proj"])
    if return_state:
        h, c, n, m = carry
        return out, {"h": h, "c": c, "n": n, "m": m}
    return out


def slstm_state_specs(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {(k,): ParamSpec((batch, d), ("batch", "embed"), dtype=jnp.float32, init="zeros")
            for k in ("h", "c", "n", "m")}


def slstm_decode(params, state, x, *, cfg: ModelConfig):
    carry = (state["h"], state["c"], state["n"], state["m"])
    new = _slstm_step(params, carry, x[:, 0], cfg=cfg)
    h, c, n, m = new
    out = jnp.einsum("bld,de->ble", h[:, None, :].astype(x.dtype), params["out_proj"])
    return {"h": h, "c": c, "n": n, "m": m}, out
