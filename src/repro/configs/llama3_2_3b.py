"""llama3.2-3b [dense] — 28L d=3072 24H (kv=8) ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=500_000.0, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512, dtype="float32", attn_q_chunk=16)
