"""qwen2-72b [dense] — 80L d=8192 64H (kv=8) ff=29568 vocab=152064, QKV bias.
[arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512, dtype="float32", attn_q_chunk=16)
