"""Architecture registry: the 10 assigned configs + paper-pipeline roles.

Every module exports CONFIG (full size; exercised only via the dry-run) and
smoke() (reduced same-family config that runs real steps on CPU).
"""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeCell, cell_applicable, input_specs

from repro.configs import (  # noqa: E402
    llama_3_2_vision_11b,
    mixtral_8x22b,
    llama4_maverick_400b_a17b,
    qwen1_5_4b,
    llama3_2_3b,
    deepseek_7b,
    qwen2_72b,
    xlstm_125m,
    zamba2_7b,
    whisper_small,
)

_MODULES = {
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "mixtral-8x22b": mixtral_8x22b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "qwen1.5-4b": qwen1_5_4b,
    "llama3.2-3b": llama3_2_3b,
    "deepseek-7b": deepseek_7b,
    "qwen2-72b": qwen2_72b,
    "xlstm-125m": xlstm_125m,
    "zamba2-7b": zamba2_7b,
    "whisper-small": whisper_small,
}

ARCHS: dict[str, ModelConfig] = {name: m.CONFIG for name, m in _MODULES.items()}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ModelConfig:
    return _MODULES[name].smoke()


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeCell", "get_config", "get_smoke",
           "cell_applicable", "input_specs"]
