"""whisper-small [audio] — enc-dec, 12+12L d=768 12H ff=3072 vocab=51865;
conv/mel frontend stubbed (input_specs supplies [B, 1500, d] frame embeds).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, encoder_layers=12, num_audio_frames=1500,
    tie_embeddings=True, qkv_bias=True, max_position=32768,
    attn_impl="chunked", attn_q_chunk=512,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=512, encoder_layers=2, num_audio_frames=24,
                        max_position=128, dtype="float32", attn_q_chunk=16)
