"""qwen1.5-4b [dense] — 40L d=2560 20H (kv=20, i.e. MHA) ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936, qkv_bias=True, rope_theta=5_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=512, dtype="float32", attn_q_chunk=16)
