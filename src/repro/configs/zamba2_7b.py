"""zamba2-7b [hybrid] — 81L d=3584, Mamba2 backbone (state=64) with one
shared attention block (32H kv=32, ff=14336) applied every 6 layers.
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, ssm_state=64, ssm_groups=1,
    ssm_expand=2, ssm_chunk=256, attn_every=6, rope_theta=10_000.0,
    attn_impl="chunked",
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=512, ssm_state=16, attn_every=2,
                        ssm_chunk=16, dtype="float32", attn_q_chunk=16)
