"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (kv=8) ff=14336 vocab=128256.
Cross-attention image layers every 5th layer (8 cross blocks); patch-embedding
frontend is a stub (input_specs supplies [B, 4096, d] patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500_000.0,
    cross_attn_interval=5, num_image_tokens=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512, cross_attn_interval=2,
                        num_image_tokens=16, dtype="float32", attn_q_chunk=16)
