"""deepseek-7b [dense] — 30L d=4096 32H (kv=32, MHA) ff=11008 vocab=102400.
llama-architecture. [arXiv:2401.02954; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400, rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=512, dtype="float32", attn_q_chunk=16)
