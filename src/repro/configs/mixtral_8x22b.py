"""mixtral-8x22b [moe] — 56L d=6144 48H (kv=8) ff=16384 vocab=32768,
8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, rope_theta=1_000_000.0,
    num_experts=8, experts_per_token=2, sliding_window=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512, num_experts=4, experts_per_token=2,
                        sliding_window=8, dtype="float32", attn_q_chunk=16)
