"""Model / run configuration system.

``ModelConfig`` is a frozen dataclass describing an architecture exactly; one
module per assigned architecture lives next to this file and exports ``CONFIG``
(full-size, dry-run only) and ``smoke()`` (reduced same-family config that runs
a real forward/train step on CPU).

``SHAPES`` are the assigned input-shape cells; ``input_specs`` builds the
ShapeDtypeStruct stand-ins for every model input of a given (arch, shape).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

Family = Literal["dense", "moe", "vlm", "audio", "ssm", "hybrid"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    sliding_window: int = 0          # 0 = full attention
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_interval: int = 1            # MoE every k-th layer (llama4: 2), rest dense FFN
    moe_shared_expert: bool = False  # llama4: one always-on shared expert

    # VLM (cross-attention to image patch embeddings; frontend stubbed)
    cross_attn_interval: int = 0     # every k-th layer preceded by a cross block
    num_image_tokens: int = 0        # patches provided by input_specs stub

    # encoder-decoder (whisper; conv frontend stubbed -> precomputed frames)
    encoder_layers: int = 0
    num_audio_frames: int = 0
    max_position: int = 32_768       # learned decoder position table (audio family)

    # SSM / hybrid
    ssm_state: int = 0               # Mamba2 state size N
    ssm_groups: int = 1              # B/C groups (Mamba2)
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256             # SSD chunk length
    attn_every: int = 0              # zamba2: shared attn block every k ssm layers
    slstm_every: int = 0             # xlstm: every k-th block is sLSTM (rest mLSTM)

    # implementation knobs (not architecture)
    attn_impl: str = "auto"          # auto | full | chunked | pallas
    decode_cp: bool = False          # shard_map context-parallel decode attention
    attn_q_chunk: int = 1024         # q-block size for chunked attention
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    logical_rules: str = "default"   # sharding rule-table name

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports ~O(S) long-context decode (assignment rule)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        from repro.models.registry import param_specs
        from repro import common
        return common.param_count(param_specs(self))

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts experts_per_token of experts)."""
        from repro.models.registry import param_specs
        import numpy as np
        total = 0
        for path, spec in param_specs(self).items():
            n = int(np.prod(spec.shape))
            if "experts" in spec.axes:
                e_dim = spec.shape[spec.axes.index("experts")]
                n = n * self.experts_per_token // max(e_dim, 1)
            total += n
        return total


# ---------------------------------------------------------------------------
# Assigned shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Assignment skip rules. Returns (applicable, reason-if-not)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; skipped for pure full-attention arch (see DESIGN.md)"
    return True, ""


def input_specs(cfg: ModelConfig, cell: ShapeCell, *, per_host_batch: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given cell.

    Modality frontends are stubs per the assignment: VLM gets precomputed
    patch embeddings, whisper gets precomputed audio-frame embeddings.
    """
    b = per_host_batch or cell.global_batch
    s = cell.seq_len
    i32, act = jnp.int32, cfg.activation_dtype
    sd = jax.ShapeDtypeStruct
    specs: dict = {}
    if cell.kind == "train":
        specs["tokens"] = sd((b, s), i32)
        specs["labels"] = sd((b, s), i32)
    elif cell.kind == "prefill":
        specs["tokens"] = sd((b, s), i32)
    else:  # decode: one new token against a cache of length s
        specs["tokens"] = sd((b, 1), i32)
        specs["cache_len"] = sd((), i32)
    if cfg.family == "vlm":
        specs["image_embeds"] = sd((b, cfg.num_image_tokens, cfg.d_model), act)
    if cfg.family == "audio":
        specs["audio_frames"] = sd((b, cfg.num_audio_frames, cfg.d_model), act)
    return specs
