"""xlstm-125m [ssm] — 12L d=768 4H vocab=50304; mLSTM blocks with an sLSTM
block every 4th layer (xLSTM[3:1]); d_ff=0 (mixers carry internal expansion).
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=4, ssm_chunk=256,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                        vocab_size=512, slstm_every=2, ssm_chunk=16, dtype="float32")
