"""llama4-maverick-400b-a17b [moe] — 48L d=5120 40H (kv=8) ff=8192
vocab=202048, MoE 128 experts top-1 every other layer + shared expert
(early-fusion multimodal in the release; exercised as text LM here, the
assigned input shapes are token shapes). [hf:meta-llama/Llama-4; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, rope_theta=500_000.0,
    num_experts=128, experts_per_token=1, moe_interval=2, moe_shared_expert=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512, num_experts=8, experts_per_token=1,
                        dtype="float32", attn_q_chunk=16)
