"""RetrievalBackend: the one interface every similarity consumer goes
through (§4.2 — sim-search operators are where vector-search optimizations
plug into the engine).

Two implementations:

  * ``VectorIndex`` (``index/vector_index.py``) — exact brute-force scan,
    the gold reference; scores every corpus vector per query.
  * ``IVFIndex``    (``index/ivf_index.py``)    — spherical-k-means inverted
    file with ``nprobe`` cluster pruning; scores only the probed clusters'
    vectors through the Pallas cluster-scan kernel.

Consumers (sem_search / sem_sim_join / the join sim-prefilter / sem_group_by
center scoring / sem_topk pivot selection) never touch vectors directly:
they ``build_index(...)`` (or receive one from the plan layer / the serving
``IndexRegistry``) and call ``search``/``pairwise``.  ``last_stats`` exposes
per-search accounting (scored vectors, probed clusters) so operators can
attribute retrieval cost, and ``choose_backend`` is the shared cost model
the plan optimizer and the executor use to pick exact vs IVF per node.
"""
from __future__ import annotations

import abc
import hashlib
import json
import math
import os
import threading

import numpy as np

# cost-model constants (FLOP-proportional units: one unit = scoring one
# corpus vector against one query)
IVF_MIN_CORPUS = 2048        # below this an exact scan is always cheaper
IVF_BUILD_ITERS = 10         # k-means sweeps priced into the build
IVF_TRAIN_PER_CLUSTER = 64   # quantizer trains on <= this many points/cluster
IVF_BUILD_QUERIES = 10_000   # queries a built index amortizes over (the
                             # registry shares builds across serve sessions,
                             # so serving traffic, not one call, pays it)
MIN_PROBE_FRAC = 0.02        # recall floor: never probe fewer clusters
SHARD_MIN_CORPUS = 4096      # below this a device-sharded scan can't pay
                             # the shard_map dispatch + host merge overhead
QUANT_MIN_CORPUS = 8192      # below this the exact-rerank overhead eats the
                             # int8 byte win (and fp32 tiles fit anyway)
NOMINAL_DIM = 64             # byte-cost dim when the plan layer doesn't know
                             # the embedding width (embeddings don't exist at
                             # plan time); only the fp32/int8 *ratio* matters
                             # for the decision, and that is dim-insensitive
DEFAULT_RERANK_FACTOR = 4    # quantized scan keeps rerank_factor*k
                             # candidates for the exact fp32 rerank

# score written to masked padding lanes / unfilled slots (finite: TPU-safe).
# Canonical home is here (numpy-only module) so the IVF index and the
# operator layer never pay a jax import just to read the constant; the
# Pallas/jnp kernels (repro.kernels.ref / ivf_scan) import it from here.
MASKED_SCORE = -1e30


def exact_topk(vectors: np.ndarray, queries: np.ndarray, k: int
               ) -> tuple[np.ndarray, np.ndarray]:
    """Brute-force exact top-k by unit-normalized inner product.

    Shared gold reference for the guarantee auditor's sampled recall@k
    re-scans (and anything else needing a small exact answer without
    building a ``VectorIndex``).  Pure numpy: never billed, safe on the
    audit worker thread.  -> (scores [nq, k], indices [nq, k]) descending.
    """
    v = np.atleast_2d(np.asarray(vectors, np.float32))
    q = np.atleast_2d(np.asarray(queries, np.float32))
    v = v / np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-9)
    q = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
    k = max(1, min(int(k), len(v)))
    scores = q @ v.T                                  # [nq, nc]
    part = np.argpartition(-scores, k - 1, axis=1)[:, :k]
    rows = np.arange(len(q))[:, None]
    order = np.argsort(-scores[rows, part], axis=1, kind="stable")
    idx = part[rows, order]
    return scores[rows, idx], idx


def train_sample_size(n_corpus: int, n_clusters: int) -> int:
    """Quantizer training subsample (FAISS-style): k-means sees at most
    ``IVF_TRAIN_PER_CLUSTER`` points per centroid; the full corpus is only
    assigned once afterwards."""
    return min(n_corpus, max(2048, IVF_TRAIN_PER_CLUSTER * n_clusters))


class RetrievalBackend(abc.ABC):
    """Uniform search surface over an embedded corpus."""

    kind: str = "abstract"

    def __init__(self, vectors: np.ndarray, ids: list | None = None):
        self.vectors = np.asarray(vectors, np.float32)
        self.ids = list(range(len(self.vectors))) if ids is None else list(ids)
        self._tls = threading.local()
        # serializes add()/retrain mutations; searches snapshot references
        # under it (registry-shared indexes are read by many sessions while
        # the streaming layer appends deltas)
        self._mut = threading.Lock()

    @property
    def last_stats(self) -> dict:
        """Per-search accounting ({"index", "scored_vectors",
        "probed_clusters", ...}), read by operators right after search().
        Thread-local: registry-shared indexes are searched concurrently by
        many serve sessions and each must see its own numbers."""
        return getattr(self._tls, "stats", {})

    @last_stats.setter
    def last_stats(self, value: dict) -> None:
        self._tls.stats = value

    def __len__(self) -> int:
        return len(self.vectors)

    @abc.abstractmethod
    def search(self, queries: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        """-> (scores [nq, k], indices [nq, k]) by inner product, descending."""

    def add(self, vectors: np.ndarray, ids: list | None = None) -> None:
        """Append corpus rows; positions continue from ``len(self)``, so an
        appends-only corpus delta keeps index position == snapshot row.
        The exact backend searches the concatenated corpus directly; the IVF
        backend overrides this with a delta side buffer + drift retrain."""
        v = np.atleast_2d(np.asarray(vectors, np.float32))
        if not len(v):
            return
        with self._mut:
            start = len(self.vectors)
            self.vectors = np.concatenate([self.vectors, v]) if start else v.copy()
            self.ids.extend(list(ids) if ids is not None
                            else range(start, start + len(v)))

    @abc.abstractmethod
    def pairwise(self, queries: np.ndarray) -> np.ndarray:
        """Exact full score matrix [nq, nc] (proxy-scoring consumers)."""

    def describe(self) -> dict:
        return {"kind": self.kind, "size": len(self),
                "dim": int(self.vectors.shape[1]) if self.vectors.size else 0}

    @abc.abstractmethod
    def save(self, path: str) -> None: ...


# ---------------------------------------------------------------------------
# Construction / persistence dispatch
# ---------------------------------------------------------------------------


def choose_shards(n_corpus: int, device_count: int, *,
                  requested: int | None = None,
                  min_corpus: int = SHARD_MIN_CORPUS) -> int:
    """Shard layout for a corpus: an explicit request is honored (clamped to
    the device count); otherwise shard across every device once the corpus
    is big enough to amortize the per-device dispatch.  1 = unsharded."""
    if requested is not None:
        return max(1, min(int(requested), max(device_count, 1)))
    if device_count <= 1 or n_corpus < min_corpus:
        return 1
    return device_count


def build_index(vectors: np.ndarray, ids: list | None = None, *,
                kind: str = "exact", **kw) -> RetrievalBackend:
    from repro.index.ivf_index import IVFIndex
    from repro.index.vector_index import VectorIndex
    if kind == "auto":
        # an explicitly built index (sem_index) exists to be searched many
        # times / persisted, so price the build amortized over its lifetime
        kind, nprobe = choose_backend(len(vectors), n_queries=1, shared=True)
        if kind == "ivf":
            kw.setdefault("nprobe", nprobe)
    if kind == "exact":
        return VectorIndex(vectors, ids, shards=kw.get("shards"))
    if kind == "ivf":
        return IVFIndex(vectors, ids, **kw)
    raise ValueError(f"unknown index kind {kind!r} (expected 'exact'|'ivf'|'auto')")


def load_index(path: str) -> RetrievalBackend:
    """Load a persisted index of either format (meta.json carries the kind;
    pre-RetrievalBackend directories without one are exact)."""
    from repro.index.ivf_index import IVFIndex
    from repro.index.vector_index import VectorIndex
    with open(os.path.join(path, "meta.json")) as f:
        kind = json.load(f).get("kind", "exact")
    return {"exact": VectorIndex, "ivf": IVFIndex}[kind].load(path)


# ---------------------------------------------------------------------------
# Cost model (shared by the plan optimizer and the executor's "auto" path)
# ---------------------------------------------------------------------------


def default_n_clusters(n_corpus: int) -> int:
    """FAISS-style sqrt(n) coarse quantizer size."""
    return int(min(max(8, round(math.sqrt(max(n_corpus, 1)))), 4096))


# empirical recall@k -> probe-fraction curve on clustered corpora; strongly
# concave (the last few points of recall cost most of the clusters), tuned
# against benchmarks/index_bench.py and verified there at every run
_RECALL_FRAC = ((0.80, 0.02), (0.90, 0.05), (0.95, 0.10),
                (0.99, 0.20), (1.00, 0.50))


def nprobe_for_recall(n_clusters: int, recall_target: float) -> int:
    """Map the recall knob onto a probed-cluster count by linear
    interpolation between the calibration points (a target between two
    points pays a proportional probe fraction instead of jumping to the
    next point's — recall_target=0.91 probes ~6%, not the 0.95 point's 10%);
    ``recall_target=1.0`` demands every cluster (exact-identical results)."""
    if recall_target >= 1.0:
        return n_clusters
    if recall_target <= _RECALL_FRAC[0][0]:
        frac = _RECALL_FRAC[0][1]
    else:
        frac = _RECALL_FRAC[-1][1]
        for (r0, f0), (r1, f1) in zip(_RECALL_FRAC, _RECALL_FRAC[1:]):
            if recall_target <= r1:
                frac = f0 + (recall_target - r0) / (r1 - r0) * (f1 - f0)
                break
    frac = max(MIN_PROBE_FRAC, frac)
    # epsilon absorbs float noise from the interpolation (0.06*200 must be
    # 12 probes, not ceil(12.000000000000002) = 13)
    return max(1, min(n_clusters, math.ceil(frac * n_clusters - 1e-9)))


def retrieval_costs(n_corpus: int, n_queries: int, *,
                    recall_target: float = 0.95, shared: bool = False,
                    k: int = 10, dim: int = NOMINAL_DIM,
                    rerank_factor: int = DEFAULT_RERANK_FACTOR) -> dict:
    """Byte-aware costs of serving ``n_queries`` over ``n_corpus``: exact
    scan vs fp32 IVF vs int8 IVF + exact rerank.

    The scan hot loop is memory-bound, so the cost unit is *one fp32 vector
    streamed from HBM per query* (``4*dim`` bytes); an int8 vector streams
    ``dim + 4`` bytes (payload + its f32 scale;
    ``repro.index.quant.bytes_per_vector``) and therefore costs a fraction
    of a unit, but every query additionally pays ``rerank_factor * k`` fp32
    rescans for the exact rerank that restores the recall contract.  Build
    costs stay FLOP-proportional in the same unit (one unit = one
    vector-vs-query score), exactly as before — quantization adds one cheap
    streaming pass (``0.25 * n_corpus`` units).

    ``shared=True`` models a registry-backed build reused across sessions:
    this batch is charged its per-query share of the build assuming
    ``IVF_BUILD_QUERIES`` lifetime queries.  ``shared=False`` (no registry:
    the index dies with the call) charges the whole build to this batch.

    Returns units (``exact`` / ``ivf`` / ``ivf_q``) plus the raw scanned
    bytes per query (``*_bytes_per_query``) for explain output."""
    from repro.index.quant import bytes_per_vector
    kc = default_n_clusters(n_corpus)
    nprobe = nprobe_for_recall(kc, recall_target)
    avg_cluster = n_corpus / max(kc, 1)
    fp32_vec = bytes_per_vector(dim, "none")
    int8_frac = bytes_per_vector(dim, "int8") / fp32_vec  # ~0.27 at d=64
    exact = float(n_queries * n_corpus)
    train = train_sample_size(n_corpus, kc)
    build = float(train * kc * IVF_BUILD_ITERS + n_corpus * kc)
    # one cheap streaming quant pass on top of the k-means build; amortizes
    # over serving traffic exactly like the rest of the build
    build_q = build + 0.25 * n_corpus
    if shared:
        build *= n_queries / IVF_BUILD_QUERIES
        build_q *= n_queries / IVF_BUILD_QUERIES
    scanned = kc + nprobe * avg_cluster            # vectors per query
    scan = n_queries * scanned
    # quantized: centroids stay fp32 (tiny), probed tiles stream at the int8
    # fraction, and the rerank exact-rescans rerank_factor*k rows per query
    rerank = min(rerank_factor * k, nprobe * avg_cluster)
    scan_q = n_queries * (kc + int8_frac * nprobe * avg_cluster + rerank)
    return {"exact": exact, "ivf": build + scan, "ivf_q": build_q + scan_q,
            "n_clusters": kc, "nprobe": nprobe,
            "exact_bytes_per_query": n_corpus * fp32_vec,
            "ivf_bytes_per_query": scanned * fp32_vec,
            "ivf_q_bytes_per_query": (kc * fp32_vec
                                      + nprobe * avg_cluster
                                      * bytes_per_vector(dim, "int8")
                                      + rerank * fp32_vec)}


def choose_backend(n_corpus: int, n_queries: int, *,
                   recall_target: float = 0.95,
                   min_corpus: int = IVF_MIN_CORPUS,
                   shared: bool = False) -> tuple[str, int | None]:
    """-> ("exact", None) or ("ivf", nprobe)."""
    if n_corpus < min_corpus or recall_target >= 1.0:
        return "exact", None
    c = retrieval_costs(n_corpus, n_queries, recall_target=recall_target,
                        shared=shared)
    if c["ivf"] < c["exact"]:
        return "ivf", c["nprobe"]
    return "exact", None


def choose_retrieval_config(n_corpus: int, n_queries: int, *,
                            recall_target: float = 0.95,
                            min_corpus: int = IVF_MIN_CORPUS,
                            shared: bool = False, quantize: str = "auto",
                            min_quant_corpus: int = QUANT_MIN_CORPUS,
                            k: int = 10,
                            rerank_factor: int = DEFAULT_RERANK_FACTOR) -> dict:
    """Full retrieval choice: backend kind + nprobe + tile precision.

    Extends :func:`choose_backend` with the byte/recall trade: when IVF wins
    and the corpus clears ``min_quant_corpus``, int8 tiles are chosen
    exactly when their byte-aware cost (``ivf_q``: int8 scan + exact-rerank
    overhead) beats the fp32 scan.  ``quantize`` pins the answer ("int8" /
    "none") or lets the cost model decide ("auto"); exact retrieval is
    always full precision.

    -> {"kind", "nprobe", "quantize", "costs"} — ``costs`` is the
    :func:`retrieval_costs` dict when IVF was priced, else None."""
    if quantize not in ("auto", "int8", "none"):
        raise ValueError(f"quantize={quantize!r} (expected 'auto'|'int8'|'none')")
    kind, nprobe = choose_backend(n_corpus, n_queries,
                                  recall_target=recall_target,
                                  min_corpus=min_corpus, shared=shared)
    if kind != "ivf":
        return {"kind": kind, "nprobe": None, "quantize": "none", "costs": None}
    c = retrieval_costs(n_corpus, n_queries, recall_target=recall_target,
                        shared=shared, k=k, rerank_factor=rerank_factor)
    if quantize == "int8":
        chosen = "int8"
    elif quantize == "none" or n_corpus < min_quant_corpus:
        chosen = "none"
    else:
        chosen = "int8" if c["ivf_q"] < c["ivf"] else "none"
    return {"kind": kind, "nprobe": nprobe, "quantize": chosen, "costs": c}


# ---------------------------------------------------------------------------
# Fingerprinting (cross-session index sharing keys)
# ---------------------------------------------------------------------------


def embedder_key(embedder) -> str:
    """Stable identity of the *backend* embedding model, unwrapping the
    per-session accounting/dispatch layers so two serve sessions over the
    same model share one index."""
    key = getattr(embedder, "index_key", None)
    if key is not None:
        return key
    return f"{type(embedder).__name__}@{id(embedder):x}"


def corpus_fingerprint(texts, embedder) -> str:
    h = hashlib.sha1()
    h.update(embedder_key(embedder).encode())
    for t in texts:
        b = str(t).encode("utf-8", "replace")
        # length prefix, not a separator: ["a\x1fb"] must not collide
        # with ["a", "b"] (an aliased registry key would silently serve a
        # different corpus's index)
        h.update(f"{len(b)}:".encode())
        h.update(b)
    return h.hexdigest()
