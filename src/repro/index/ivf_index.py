"""IVF (inverted-file) ANN index: the pruned RetrievalBackend.

Build: spherical k-means (`index/kmeans.py`) coarse-quantizes the corpus
into ``n_clusters`` inverted lists, laid out as padded per-cluster tiles
``store [kc, L, d]`` (L = max cluster size rounded up to the 128-lane
width) with a validity mask — the static-shape layout the Pallas cluster
scan (`kernels/ivf_scan.py`) gathers from.

Search: every query is scored against its top-``nprobe`` clusters (by
centroid score) — work is O(sum of probed cluster sizes) instead of
O(corpus).  Queries are processed in blocks of ``block_q``; a block scans
the concatenation of its queries' probe lists, so each query additionally
sees its blockmates' clusters (recall can only improve; ``last_stats``
counts the unique clusters actually scanned).  ``nprobe`` is the recall
knob: the recall@k-vs-exact contract is measured (tests/test_index.py,
benchmarks/index_bench.py), and ``nprobe = n_clusters`` degenerates to
exact-identical results.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.index.backend import (MASKED_SCORE, RetrievalBackend,
                                 default_n_clusters, nprobe_for_recall,
                                 train_sample_size)
from repro.index.kmeans import kmeans

_LANE = 128        # pad L to the TPU lane width so MXU tiles stay aligned
_BALANCE_FACTOR = 4  # cap cluster size at this multiple of the mean: every
                     # tile is padded to the LARGEST cluster, so one skewed
                     # list would otherwise inflate the whole store


class IVFIndex(RetrievalBackend):
    kind = "ivf"

    def __init__(self, vectors: np.ndarray, ids: list | None = None, *,
                 n_clusters: int | None = None, nprobe: int | None = None,
                 recall_target: float = 0.95, kmeans_iters: int = 10,
                 block_q: int = 8, seed: int = 0,
                 _centroids: np.ndarray | None = None,
                 _assign: np.ndarray | None = None):
        super().__init__(vectors, ids)
        norms = np.linalg.norm(self.vectors, axis=1, keepdims=True)
        unit = self.vectors / np.maximum(norms, 1e-9)
        n = len(unit)
        self.n_clusters = min(n_clusters or default_n_clusters(n), max(n, 1))
        self.block_q = int(block_q)
        self.seed = seed
        self.kmeans_iters = kmeans_iters
        if _centroids is not None and _assign is not None:  # load() fast path
            self.centroids, self.assign = _centroids, _assign
        else:
            # FAISS-style: train the quantizer on a subsample, then assign
            # the full corpus in one pass (the cost model prices exactly this)
            train_n = train_sample_size(n, self.n_clusters)
            if train_n < n:
                rng = np.random.default_rng(seed)
                sample = unit[rng.choice(n, size=train_n, replace=False)]
                self.centroids, _ = kmeans(sample, self.n_clusters,
                                           iters=kmeans_iters, seed=seed)
                self.assign = self._assign_all(unit)
            else:
                self.centroids, self.assign = kmeans(
                    unit, self.n_clusters, iters=kmeans_iters, seed=seed)
        self.n_clusters = len(self.centroids)
        self.nprobe = int(nprobe if nprobe is not None
                          else nprobe_for_recall(self.n_clusters, recall_target))
        self._build_store(unit)

    def _assign_all(self, unit: np.ndarray, chunk: int = 8192) -> np.ndarray:
        out = np.empty(len(unit), np.int64)
        for s in range(0, len(unit), chunk):
            out[s:s + chunk] = np.argmax(unit[s:s + chunk] @ self.centroids.T,
                                         axis=1)
        return out

    def _cluster_cap(self, n: int) -> int:
        kc = max(self.n_clusters, 1)
        return max(_LANE, int(np.ceil(_BALANCE_FACTOR * n / kc)))

    def _rebalance(self, unit: np.ndarray, cap: int) -> None:
        """Bounded-capacity repair: move an oversized cluster's lowest-
        affinity members to their next-best centroid with room.  Every
        vector stays in exactly one list (the degenerate nprobe=all contract
        is untouched); only the inverted-list layout changes."""
        sizes = np.bincount(self.assign, minlength=self.n_clusters)
        overflow: list[int] = []
        for j in np.flatnonzero(sizes > cap):
            m = np.flatnonzero(self.assign == j)
            order = np.argsort(-(unit[m] @ self.centroids[j]))
            overflow.extend(m[order[cap:]].tolist())
            sizes[j] = cap
        for i in overflow:
            prefs = np.argsort(-(unit[i] @ self.centroids.T))
            dest = next(int(c) for c in prefs if sizes[c] < cap)
            self.assign[i] = dest
            sizes[dest] += 1

    def _build_store(self, unit: np.ndarray) -> None:
        kc = self.n_clusters
        cap = self._cluster_cap(len(unit))
        if len(unit) and np.bincount(self.assign, minlength=kc).max() > cap:
            self._rebalance(unit, cap)
        members = [np.flatnonzero(self.assign == j) for j in range(kc)]
        self.cluster_sizes = np.asarray([len(m) for m in members], np.int64)
        L = int(max(self.cluster_sizes.max(initial=1), 1))
        L = -(-L // _LANE) * _LANE
        d = unit.shape[1] if unit.ndim == 2 else 0
        self.store = np.zeros((kc, L, d), np.float32)
        self.store_mask = np.zeros((kc, L), np.float32)
        self.store_ids = np.full((kc, L), -1, np.int32)
        for j, m in enumerate(members):
            self.store[j, : len(m)] = unit[m]
            self.store_mask[j, : len(m)] = 1.0
            self.store_ids[j, : len(m)] = m
        # worst-case probe floor: any m probed clusters hold at least the sum
        # of the m smallest lists, so k results need at most this many probes
        self._size_cumsum = np.cumsum(np.sort(self.cluster_sizes))

    def _min_probes(self, k: int) -> int:
        need = min(k, int(self._size_cumsum[-1]) if len(self._size_cumsum) else 0)
        if need <= 0:
            return 1
        return int(np.searchsorted(self._size_cumsum, need) + 1)

    # -- search ------------------------------------------------------------
    def search(self, queries: np.ndarray, k: int, *, nprobe: int | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops as kops
        q = np.atleast_2d(np.asarray(queries, np.float32))
        nq = len(q)
        k = min(k, len(self))
        if nq == 0:  # an upstream operator emptied the query side
            self.last_stats = {"index": self.kind, "scored_vectors": 0,
                               "probed_clusters": 0, "nprobe": 0,
                               "n_clusters": int(self.n_clusters)}
            return np.zeros((0, k), np.float32), np.zeros((0, k), np.int64)
        nprobe_eff = min(max(nprobe or self.nprobe, self._min_probes(k)),
                         self.n_clusters)
        scores, probe_blocks = kops.ivf_search(
            q, self.centroids, self.store, self.store_mask,
            nprobe=nprobe_eff, block_q=self.block_q)
        # candidate ids per block, broadcast to every query row in the block
        cand_ids = self.store_ids[probe_blocks].reshape(len(probe_blocks), -1)
        out_s, out_i = self._topk_unique(scores, cand_ids, k)

        scored = 0
        probed_unique = 0
        for b in range(len(probe_blocks)):
            real_q = min(nq - b * self.block_q, self.block_q)
            uniq = np.unique(probe_blocks[b])
            probed_unique += len(uniq)
            scored += real_q * int(self.cluster_sizes[uniq].sum())
        self.last_stats = {"index": self.kind, "scored_vectors": scored,
                           "probed_clusters": int(probed_unique),
                           "nprobe": int(nprobe_eff),
                           "n_clusters": int(self.n_clusters)}
        return out_s, out_i

    def _topk_unique(self, scores: np.ndarray, cand_ids: np.ndarray, k: int
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Per-query top-k over the scanned candidates, deduplicating rows a
        block scanned more than once (identical scores, so dedup is safe).
        ``scores`` has one row per query, ``cand_ids`` one row per block."""
        nq = len(scores)
        out_s = np.full((nq, k), MASKED_SCORE, np.float32)
        out_i = np.zeros((nq, k), np.int64)
        # a candidate id repeats at most block_q times (once per blockmate's
        # probe list), so the top k*block_q scores are guaranteed to hold k
        # unique ids — argpartition to that bound instead of sorting the
        # whole slots*L row (which can exceed the corpus size)
        for r in range(nq):
            row = scores[r]
            row_ids = cand_ids[r // self.block_q]
            bound = min(len(row), k * self.block_q)
            part = np.argpartition(-row, bound - 1)[:bound] \
                if bound < len(row) else np.arange(len(row))
            order = part[np.argsort(-row[part], kind="stable")]
            seen: set[int] = set()
            c = 0
            for t in order:
                i = int(row_ids[t])
                if i < 0 or i in seen:
                    continue
                seen.add(i)
                out_s[r, c] = row[t]
                out_i[r, c] = i
                c += 1
                if c == k:
                    break
        return out_s, out_i

    def pairwise(self, queries: np.ndarray) -> np.ndarray:
        """Exact full matrix (proxy-calibration consumers need every score)."""
        from repro.kernels import ops as kops
        return kops.similarity(np.asarray(queries, np.float32), self.vectors)

    def describe(self) -> dict:
        return {**super().describe(), "n_clusters": int(self.n_clusters),
                "nprobe": int(self.nprobe), "block_q": self.block_q}

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "vectors.npy"), self.vectors)
        np.save(os.path.join(path, "centroids.npy"), self.centroids)
        np.save(os.path.join(path, "assign.npy"), self.assign.astype(np.int32))
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump({"kind": self.kind, "ids": self.ids,
                       "dim": int(self.vectors.shape[1]),
                       "n_clusters": int(self.n_clusters),
                       "nprobe": int(self.nprobe), "block_q": self.block_q,
                       "seed": self.seed}, f)

    @classmethod
    def load(cls, path: str) -> "IVFIndex":
        vectors = np.load(os.path.join(path, "vectors.npy"))
        centroids = np.load(os.path.join(path, "centroids.npy"))
        assign = np.load(os.path.join(path, "assign.npy")).astype(np.int64)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return cls(vectors, meta["ids"], n_clusters=meta["n_clusters"],
                   nprobe=meta["nprobe"], block_q=meta["block_q"],
                   seed=meta.get("seed", 0), _centroids=centroids,
                   _assign=assign)
