"""IVF (inverted-file) ANN index: the pruned RetrievalBackend.

Build: spherical k-means (`index/kmeans.py`) coarse-quantizes the corpus
into ``n_clusters`` inverted lists, laid out as padded per-cluster tiles
``store [kc, L, d]`` (L = max cluster size rounded up to the 128-lane
width) with a validity mask — the static-shape layout the Pallas cluster
scan (`kernels/ivf_scan.py`) gathers from.

Search: every query is scored against its top-``nprobe`` clusters (by
centroid score) — work is O(sum of probed cluster sizes) instead of
O(corpus).  Queries are processed in blocks of ``block_q``; a block scans
the concatenation of its queries' probe lists, so each query additionally
sees its blockmates' clusters (recall can only improve; ``last_stats``
counts the unique clusters actually scanned).  ``nprobe`` is the recall
knob: the recall@k-vs-exact contract is measured (tests/test_index.py,
benchmarks/index_bench.py), and ``nprobe = n_clusters`` degenerates to
exact-identical results.

Streaming: ``add()`` appends rows to a *delta side buffer* instead of
rebuilding — the quantizer is untouched, and every search exact-scans the
(small) buffer alongside the probed clusters and merges top-k
(``kernels.ops.ivf_delta_search``; jnp contract ``ref.ivf_delta_search_ref``).
Delta rows therefore have recall 1.0 by construction and base recall is
unchanged.  A drift detector watches the spill fraction
(|delta| / |clustered rows|): past ``spill_threshold`` the buffer is folded
in by retraining the quantizer over the full corpus — in a background
thread by default (searches keep running against the old store + buffer
until the atomic swap), synchronously with ``retrain="sync"``, or never
with ``retrain="off"``.  A sync retrain is bit-identical to a fresh build
over the concatenated corpus with the same seed/params (tests enforce it).

Quantization: ``quantize="int8"`` stores the tiles as symmetric per-vector
int8 (`index/quant.py`) — ``d + 4`` HBM bytes per scanned vector instead of
``4 * d`` — and the cluster scan dequantizes in-kernel
(`kernels/ivf_scan_q.py`).  Quantized scores rank a candidate pool of
``rerank_factor * k`` per query, which an exact fp32 rerank
(:meth:`_exact_rerank`, reading the raw ``self.vectors`` rows the index
already keeps) rescores before the final top-k — the measured recall@k
contract is preserved while the scan streams ~4x fewer bytes.  The delta
side buffer quantizes incrementally in ``add()``; retrains re-quantize from
the fp32 corpus, so no drift accumulates.  ``quantize="none"`` (default)
leaves every code path and result bit-identical to the unquantized index.
"""
from __future__ import annotations

import json
import os
import threading

import numpy as np

from repro.index.backend import (DEFAULT_RERANK_FACTOR, MASKED_SCORE,
                                 RetrievalBackend, default_n_clusters,
                                 nprobe_for_recall, train_sample_size)
from repro.index.kmeans import kmeans
from repro.index.quant import bytes_per_vector, quantize_rows, quantize_tiles
from repro.obs import audit as _audit

_LANE = 128        # pad L to the TPU lane width so MXU tiles stay aligned
_BALANCE_FACTOR = 4  # cap cluster size at this multiple of the mean: every
                     # tile is padded to the LARGEST cluster, so one skewed
                     # list would otherwise inflate the whole store


class IVFIndex(RetrievalBackend):
    kind = "ivf"

    def __init__(self, vectors: np.ndarray, ids: list | None = None, *,
                 n_clusters: int | None = None, nprobe: int | None = None,
                 recall_target: float = 0.95, kmeans_iters: int = 10,
                 block_q: int = 8, seed: int = 0,
                 spill_threshold: float = 0.10, retrain: str = "background",
                 shards: int | None = None, quantize: str = "none",
                 rerank_factor: int = DEFAULT_RERANK_FACTOR,
                 _centroids: np.ndarray | None = None,
                 _assign: np.ndarray | None = None):
        super().__init__(vectors, ids)
        if quantize not in ("none", "int8"):
            raise ValueError(f"quantize={quantize!r} (expected 'none'|'int8')")
        self.quantize = quantize
        self.rerank_factor = max(int(rerank_factor), 1)
        # shards > 1 distributes the inverted-file tiles across devices and
        # scans probed clusters on their home device (ops.sharded_ivf_search)
        # — scores, and therefore results, are identical to unsharded
        self.shards = int(shards) if shards and shards > 1 else None
        if retrain not in ("background", "sync", "off"):
            raise ValueError(f"retrain={retrain!r} (expected "
                             "'background'|'sync'|'off')")
        norms = np.linalg.norm(self.vectors, axis=1, keepdims=True)
        unit = self.vectors / np.maximum(norms, 1e-9)
        n = len(unit)
        self._n_clusters_arg = n_clusters       # retrain re-derives from size
        self.n_clusters = min(n_clusters or default_n_clusters(n), max(n, 1))
        self.block_q = int(block_q)
        self.seed = seed
        self.kmeans_iters = kmeans_iters
        self.recall_target = recall_target
        self._nprobe_explicit = nprobe is not None
        self.spill_threshold = float(spill_threshold)
        self.retrain_mode = retrain
        self.retrains = 0
        self._retrain_thread: threading.Thread | None = None
        self._retrain_queued = False
        self._retrain_guard = threading.Lock()  # one retrain at a time
        d = unit.shape[1] if unit.ndim == 2 else 0
        self._delta_unit = np.zeros((0, d), np.float32)
        self._delta_pos = np.zeros(0, np.int64)
        self._delta_q = np.zeros((0, d), np.int8)
        self._delta_scales = np.zeros(0, np.float32)
        if _centroids is not None and _assign is not None:  # load() fast path
            self.centroids, self.assign = _centroids, _assign
        else:
            self.centroids, self.assign = self._train(unit)
        self.n_clusters = len(self.centroids)
        self.nprobe = int(nprobe if nprobe is not None
                          else nprobe_for_recall(self.n_clusters, recall_target))
        self._build_store(unit)

    def _train(self, unit: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """FAISS-style: train the quantizer on a subsample, then assign the
        full corpus in one pass (the cost model prices exactly this)."""
        n = len(unit)
        kc = min(self._n_clusters_arg or default_n_clusters(n), max(n, 1))
        train_n = train_sample_size(n, kc)
        if train_n < n:
            rng = np.random.default_rng(self.seed)
            sample = unit[rng.choice(n, size=train_n, replace=False)]
            centroids, _ = kmeans(sample, kc, iters=self.kmeans_iters,
                                  seed=self.seed)
            return centroids, self._assign_all(unit, centroids)
        return kmeans(unit, kc, iters=self.kmeans_iters, seed=self.seed)

    def _assign_all(self, unit: np.ndarray, centroids: np.ndarray | None = None,
                    chunk: int = 8192) -> np.ndarray:
        centroids = self.centroids if centroids is None else centroids
        out = np.empty(len(unit), np.int64)
        for s in range(0, len(unit), chunk):
            out[s:s + chunk] = np.argmax(unit[s:s + chunk] @ centroids.T,
                                         axis=1)
        return out

    def _cluster_cap(self, n: int) -> int:
        kc = max(self.n_clusters, 1)
        return max(_LANE, int(np.ceil(_BALANCE_FACTOR * n / kc)))

    def _rebalance(self, unit: np.ndarray, cap: int) -> None:
        """Bounded-capacity repair: move an oversized cluster's lowest-
        affinity members to their next-best centroid with room.  Every
        vector stays in exactly one list (the degenerate nprobe=all contract
        is untouched); only the inverted-list layout changes."""
        sizes = np.bincount(self.assign, minlength=self.n_clusters)
        overflow: list[int] = []
        for j in np.flatnonzero(sizes > cap):
            m = np.flatnonzero(self.assign == j)
            order = np.argsort(-(unit[m] @ self.centroids[j]))
            overflow.extend(m[order[cap:]].tolist())
            sizes[j] = cap
        for i in overflow:
            prefs = np.argsort(-(unit[i] @ self.centroids.T))
            dest = next(int(c) for c in prefs if sizes[c] < cap)
            self.assign[i] = dest
            sizes[dest] += 1

    def _build_store(self, unit: np.ndarray) -> None:
        kc = self.n_clusters
        cap = self._cluster_cap(len(unit))
        if len(unit) and np.bincount(self.assign, minlength=kc).max() > cap:
            self._rebalance(unit, cap)
        members = [np.flatnonzero(self.assign == j) for j in range(kc)]
        self.cluster_sizes = np.asarray([len(m) for m in members], np.int64)
        L = int(max(self.cluster_sizes.max(initial=1), 1))
        L = -(-L // _LANE) * _LANE
        d = unit.shape[1] if unit.ndim == 2 else 0
        store = np.zeros((kc, L, d), np.float32)
        self.store_mask = np.zeros((kc, L), np.float32)
        self.store_ids = np.full((kc, L), -1, np.int32)
        for j, m in enumerate(members):
            store[j, : len(m)] = unit[m]
            self.store_mask[j, : len(m)] = 1.0
            self.store_ids[j, : len(m)] = m
        if self.quantize == "int8":
            # quantized tiles replace the fp32 store entirely — the memory
            # saving is real, not a shadow copy; exact rerank reads the raw
            # corpus rows the base index already keeps (self.vectors)
            self.store_q, self.store_scales = quantize_tiles(store)
            self.store = None
        else:
            self.store = store
            self.store_q = self.store_scales = None
        # worst-case probe floor: any m probed clusters hold at least the sum
        # of the m smallest lists, so k results need at most this many probes
        self._size_cumsum = np.cumsum(np.sort(self.cluster_sizes))

    def _min_probes(self, k: int, size_cumsum: np.ndarray,
                    n_delta: int) -> int:
        # the delta buffer is exact-scanned, so it supplies n_delta of the k
        # candidates for free; the probe floor only covers the remainder
        in_store = int(size_cumsum[-1]) if len(size_cumsum) else 0
        need = min(max(k - n_delta, 0), in_store)
        if need <= 0:
            return 1
        return int(np.searchsorted(size_cumsum, need) + 1)

    # -- streaming delta path ----------------------------------------------
    @property
    def n_clustered(self) -> int:
        """Rows covered by the trained quantizer (the rest sit in the delta
        side buffer)."""
        return len(self.vectors) - len(self._delta_pos)

    @property
    def delta_rows(self) -> int:
        return len(self._delta_pos)

    def drift(self) -> float:
        """Spill fraction: |delta buffer| / |clustered rows|."""
        with self._mut:
            return len(self._delta_pos) / max(self.n_clustered, 1)

    def add(self, vectors: np.ndarray, ids: list | None = None) -> None:
        """Append rows to the delta side buffer — O(delta), no rebuild.
        Past ``spill_threshold`` the drift detector triggers a retrain per
        ``retrain_mode`` (background by default)."""
        v = np.atleast_2d(np.asarray(vectors, np.float32))
        if not len(v):
            return
        with self._mut:
            start = len(self.vectors)
            self.vectors = np.concatenate([self.vectors, v]) if start else v.copy()
            self.ids.extend(list(ids) if ids is not None
                            else range(start, start + len(v)))
            unit = v / np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-9)
            self._delta_unit = np.concatenate([self._delta_unit, unit]) \
                if len(self._delta_unit) else unit
            self._delta_pos = np.concatenate(
                [self._delta_pos, np.arange(start, start + len(v), dtype=np.int64)])
            if self.quantize == "int8":
                # quantize incrementally: per-vector scales are independent,
                # so appending never re-touches earlier buffer rows
                dq, dscales = quantize_rows(unit)
                self._delta_q = np.concatenate([self._delta_q, dq]) \
                    if len(self._delta_q) else dq
                self._delta_scales = np.concatenate(
                    [self._delta_scales, dscales])
            spill = len(self._delta_pos) / max(self.n_clustered, 1)
        if spill > self.spill_threshold and self.retrain_mode != "off":
            self.retrain(wait=self.retrain_mode == "sync")

    def retrain(self, wait: bool = True) -> None:
        """Fold the delta buffer into the quantizer: rebuild k-means +
        inverted lists over the full corpus (same seed/params => identical
        to a fresh build), then atomically swap stores.  ``wait=False``
        runs in a daemon thread; searches keep using the old store + buffer
        until the swap."""
        if wait:
            self._retrain()
            return
        with self._mut:
            if self._retrain_queued:
                return                          # one background retrain at a time
            self._retrain_queued = True
            t = threading.Thread(target=self._retrain, daemon=True,
                                 name="ivf-retrain")
            self._retrain_thread = t
        t.start()

    def _retrain(self) -> None:
        with self._retrain_guard:
            try:
                with self._mut:
                    vectors = self.vectors      # arrays are replaced, never
                    n = len(vectors)            # resized: safe to read outside
                if n == 0:
                    return
                unit = vectors / np.maximum(
                    np.linalg.norm(vectors, axis=1, keepdims=True), 1e-9)
                centroids, assign = self._train(unit)  # heavy part: unlocked
                with self._mut:
                    self.centroids, self.assign = centroids, assign
                    self.n_clusters = len(centroids)
                    if not self._nprobe_explicit:
                        self.nprobe = int(nprobe_for_recall(self.n_clusters,
                                                            self.recall_target))
                    self._build_store(unit)
                    keep = self._delta_pos >= n  # rows added mid-retrain stay
                    self._delta_unit = self._delta_unit[keep]
                    self._delta_pos = self._delta_pos[keep]
                    if self.quantize == "int8":
                        self._delta_q = self._delta_q[keep]
                        self._delta_scales = self._delta_scales[keep]
                    self.retrains += 1
            finally:
                with self._mut:
                    self._retrain_queued = False

    def wait_retrain(self, timeout: float | None = None) -> None:
        t = self._retrain_thread
        if t is not None and t.is_alive():
            t.join(timeout)

    # -- search ------------------------------------------------------------
    def search(self, queries: np.ndarray, k: int, *, nprobe: int | None = None,
               max_pos: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        """``max_pos`` bounds results to positions < max_pos (the snapshot
        cutoff for version-pinned queries; see ``VectorIndex.search``)."""
        from repro.kernels import ops as kops
        q = np.atleast_2d(np.asarray(queries, np.float32))
        nq = len(q)
        with self._mut:   # consistent (store, delta) snapshot vs add/retrain
            centroids, store = self.centroids, self.store
            store_q, store_scales = self.store_q, self.store_scales
            store_mask, store_ids = self.store_mask, self.store_ids
            cluster_sizes, size_cumsum = self.cluster_sizes, self._size_cumsum
            delta_unit, delta_pos = self._delta_unit, self._delta_pos
            delta_q, delta_scales = self._delta_q, self._delta_scales
            n_clusters, nprobe_default = self.n_clusters, self.nprobe
            vectors, n_total = self.vectors, len(self.vectors)
        quantized = self.quantize == "int8"
        d = q.shape[1] if q.ndim == 2 else 0
        nd = len(delta_pos)
        k = min(k, n_total if max_pos is None else min(n_total, max_pos))
        # only delta rows inside the snapshot cutoff count toward the probe
        # floor: rows beyond it are filtered out of the top-k
        nd_floor = nd if max_pos is None else int((delta_pos < max_pos).sum())
        if nq == 0:  # an upstream operator emptied the query side
            self.last_stats = {"index": self.kind, "scored_vectors": 0,
                               "probed_clusters": 0, "nprobe": 0,
                               "n_clusters": int(n_clusters), "delta_rows": nd,
                               "quantize": self.quantize, "scanned_bytes": 0,
                               "reranked": 0}
            return np.zeros((0, k), np.float32), np.zeros((0, k), np.int64)
        # the quantized scan ranks a wider candidate pool so the exact fp32
        # rerank has headroom to repair int8 ranking error around the top-k
        k_cand = min(self.rerank_factor * k, n_total) if quantized else k
        nprobe_eff = min(max(nprobe or nprobe_default,
                             self._min_probes(k_cand, size_cumsum, nd_floor)),
                         n_clusters)
        # accounting uses the split the dispatch actually runs (clamped to
        # the device count on the shard_map path)
        shards = None
        if self.shards and n_clusters >= self.shards:
            shards = kops.effective_shards(self.shards)
            shards = shards if shards > 1 else None
        if shards:
            # sharded probed-cluster scan; the (small) delta side buffer is
            # exact-scanned on host and concatenated, exactly like
            # ops.ivf_delta_search assembles it
            if quantized:
                scores, probe_blocks = kops.sharded_ivf_search_q(
                    q, centroids, store_q, store_scales, store_mask,
                    nprobe=nprobe_eff, shards=shards, block_q=self.block_q)
            else:
                scores, probe_blocks = kops.sharded_ivf_search(
                    q, centroids, store, store_mask,
                    nprobe=nprobe_eff, shards=shards, block_q=self.block_q)
            if nd:
                if quantized:
                    from repro.index.quant import quantized_scores
                    qn = q / np.maximum(
                        np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
                    ds = quantized_scores(qn, delta_q, delta_scales)
                else:
                    ds = kops.similarity(q, delta_unit)
                scores = np.concatenate(
                    [scores, np.asarray(ds, np.float32)], axis=1)
        elif nd:
            if quantized:
                scores, probe_blocks = kops.ivf_delta_search_q(
                    q, centroids, store_q, store_scales, store_mask,
                    delta_q, delta_scales,
                    nprobe=nprobe_eff, block_q=self.block_q)
            else:
                scores, probe_blocks = kops.ivf_delta_search(
                    q, centroids, store, store_mask, delta_unit,
                    nprobe=nprobe_eff, block_q=self.block_q)
        elif quantized:
            scores, probe_blocks = kops.ivf_search_q(
                q, centroids, store_q, store_scales, store_mask,
                nprobe=nprobe_eff, block_q=self.block_q)
        else:
            scores, probe_blocks = kops.ivf_search(
                q, centroids, store, store_mask,
                nprobe=nprobe_eff, block_q=self.block_q)
        # candidate ids per block: the probed clusters' rows (broadcast to
        # every query row in the block) plus the delta buffer's positions
        cand_ids = store_ids[probe_blocks].reshape(len(probe_blocks), -1)
        if nd:
            cand_ids = np.concatenate(
                [cand_ids,
                 np.broadcast_to(delta_pos, (len(probe_blocks), nd))], axis=1)
        out_s, out_i = self._topk_unique(scores, cand_ids, k_cand,
                                         max_pos=max_pos)
        reranked = 0
        if quantized:
            out_s, out_i, reranked = self._exact_rerank(q, out_s, out_i, k,
                                                        vectors)

        scored = nq * nd
        probed_unique = 0
        local_kc = -(-n_clusters // shards) if shards else n_clusters
        per_shard = np.zeros(shards or 1, np.int64)
        for b in range(len(probe_blocks)):
            real_q = min(nq - b * self.block_q, self.block_q)
            uniq = np.unique(probe_blocks[b])
            probed_unique += len(uniq)
            scored += real_q * int(cluster_sizes[uniq].sum())
            if shards:  # each cluster is scanned by its home device only
                np.add.at(per_shard, uniq // local_kc,
                          real_q * cluster_sizes[uniq])
        # dtype-aware bytes streamed through the scan: every scored vector
        # costs its stored width, plus (int8 only) the fp32 rows the exact
        # rerank re-reads from the raw corpus
        scanned_bytes = scored * bytes_per_vector(d, self.quantize)
        if quantized:
            scanned_bytes += reranked * bytes_per_vector(d, "none")
        self.last_stats = {"index": self.kind, "scored_vectors": scored,
                           "probed_clusters": int(probed_unique),
                           "nprobe": int(nprobe_eff),
                           "n_clusters": int(n_clusters),
                           "delta_rows": nd, "delta_scored": nq * nd,
                           "quantize": self.quantize,
                           "scanned_bytes": int(scanned_bytes),
                           "reranked": int(reranked)}
        if shards:
            self.last_stats.update(
                shards=int(shards),
                scored_vectors_per_shard=int(per_shard.max()) + nq * nd)
        # guarantee auditing: a budgeted sample of these queries gets an
        # exact re-scan of the same snapshot (vectors is the under-lock
        # reference; appends/retrain replace the arrays, never mutate them),
        # estimating live recall@k against recall_target — covering the
        # delta-buffer and int8 paths by construction
        _audit.emit_search(self, q, out_s, out_i, k,
                           vectors=vectors,
                           n_cut=n_total if max_pos is None
                           else min(n_total, max_pos),
                           recall_target=self.recall_target)
        return out_s, out_i

    def _topk_unique(self, scores: np.ndarray, cand_ids: np.ndarray, k: int,
                     max_pos: int | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Per-query top-k over the scanned candidates, deduplicating rows a
        block scanned more than once (identical scores, so dedup is safe).
        ``scores`` has one row per query, ``cand_ids`` one row per block."""
        nq = len(scores)
        out_s = np.full((nq, k), MASKED_SCORE, np.float32)
        out_i = np.zeros((nq, k), np.int64)
        # a candidate id repeats at most block_q times (once per blockmate's
        # probe list; delta-buffer candidates appear exactly once), so the
        # top k*block_q scores are guaranteed to hold k unique ids —
        # argpartition to that bound instead of sorting the whole slots*L
        # row (which can exceed the corpus size).  A max_pos cutoff
        # invalidates an unbounded number of top candidates, so that (rare,
        # race-window) path sorts the full row instead.
        limit = np.inf if max_pos is None else max_pos
        for r in range(nq):
            row = scores[r]
            row_ids = cand_ids[r // self.block_q]
            bound = len(row) if max_pos is not None \
                else min(len(row), k * self.block_q)
            part = np.argpartition(-row, bound - 1)[:bound] \
                if bound < len(row) else np.arange(len(row))
            order = part[np.argsort(-row[part], kind="stable")]
            seen: set[int] = set()
            c = 0
            for t in order:
                i = int(row_ids[t])
                if i < 0 or i >= limit or i in seen:
                    continue
                seen.add(i)
                out_s[r, c] = row[t]
                out_i[r, c] = i
                c += 1
                if c == k:
                    break
        return out_s, out_i

    def _exact_rerank(self, q: np.ndarray, cand_s: np.ndarray,
                      cand_i: np.ndarray, k: int, vectors: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray, int]:
        """Exact fp32 rescore of the quantized candidate pool: gather the raw
        corpus rows for each query's top ``rerank_factor*k`` int8 candidates,
        rescore them in full precision (unit rows x unit query — the same
        math the fp32 scan computes), keep the top ``k``.  Returned *scores*
        are therefore exact; int8 error only survives in which rows made the
        candidate pool, which the pool's width absorbs.  -> (scores [nq, k],
        ids [nq, k], total rows reranked)."""
        nq = len(q)
        qn = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
        out_s = np.full((nq, k), MASKED_SCORE, np.float32)
        out_i = np.zeros((nq, k), np.int64)
        reranked = 0
        for r in range(nq):
            valid = cand_s[r] > MASKED_SCORE / 2
            ids = cand_i[r][valid].astype(np.int64)
            if not len(ids):
                continue
            rows = vectors[ids]
            rows = rows / np.maximum(
                np.linalg.norm(rows, axis=1, keepdims=True), 1e-9)
            exact = (rows @ qn[r]).astype(np.float32)
            order = np.argsort(-exact, kind="stable")[:k]
            out_s[r, : len(order)] = exact[order]
            out_i[r, : len(order)] = ids[order]
            reranked += len(ids)
        return out_s, out_i, reranked

    def pairwise(self, queries: np.ndarray) -> np.ndarray:
        """Exact full matrix (proxy-calibration consumers need every score)."""
        from repro.kernels import ops as kops
        return kops.similarity(np.asarray(queries, np.float32), self.vectors)

    def describe(self) -> dict:
        out = {**super().describe(), "n_clusters": int(self.n_clusters),
               "nprobe": int(self.nprobe), "block_q": self.block_q,
               "delta_rows": self.delta_rows, "retrains": self.retrains,
               "spill_threshold": self.spill_threshold,
               "quantize": self.quantize}
        if self.quantize == "int8":
            out["rerank_factor"] = self.rerank_factor
            d = self.vectors.shape[1] if self.vectors.ndim == 2 else 0
            out["bytes_per_vector"] = bytes_per_vector(d, self.quantize)
        if self.shards:
            out["shards"] = self.shards
        return out

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        with self._mut:
            vectors, ids = self.vectors, list(self.ids)
            centroids, assign = self.centroids, self.assign
            n_base = self.n_clustered
        np.save(os.path.join(path, "vectors.npy"), vectors)
        np.save(os.path.join(path, "centroids.npy"), centroids)
        np.save(os.path.join(path, "assign.npy"), assign.astype(np.int32))
        if self.quantize == "int8":
            with self._mut:
                np.save(os.path.join(path, "store_q.npy"), self.store_q)
                np.save(os.path.join(path, "store_scales.npy"),
                        self.store_scales)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump({"kind": self.kind, "ids": ids,
                       "dim": int(vectors.shape[1]),
                       "n_clusters": int(self.n_clusters),
                       "nprobe": int(self.nprobe), "block_q": self.block_q,
                       "seed": self.seed, "n_base": int(n_base),
                       "spill_threshold": self.spill_threshold,
                       "retrain": self.retrain_mode,
                       "shards": self.shards,
                       "quantize": self.quantize,
                       "rerank_factor": self.rerank_factor}, f)

    @classmethod
    def load(cls, path: str) -> "IVFIndex":
        vectors = np.load(os.path.join(path, "vectors.npy"))
        centroids = np.load(os.path.join(path, "centroids.npy"))
        assign = np.load(os.path.join(path, "assign.npy")).astype(np.int64)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        n_base = meta.get("n_base", len(vectors))
        idx = cls(vectors[:n_base], meta["ids"][:n_base],
                  n_clusters=meta["n_clusters"], nprobe=meta["nprobe"],
                  block_q=meta["block_q"], seed=meta.get("seed", 0),
                  spill_threshold=meta.get("spill_threshold", 0.10),
                  retrain=meta.get("retrain", "background"),
                  shards=meta.get("shards"),
                  quantize=meta.get("quantize", "none"),
                  rerank_factor=meta.get("rerank_factor",
                                         DEFAULT_RERANK_FACTOR),
                  _centroids=centroids, _assign=assign)
        if idx.quantize == "int8":
            # the persisted int8 store + scales are authoritative (the
            # rebuild above re-derives identical arrays — quantization is
            # deterministic — but round-tripping the saved bytes keeps the
            # on-disk format the contract, not an implementation detail)
            idx.store_q = np.load(os.path.join(path, "store_q.npy"))
            idx.store_scales = np.load(os.path.join(path, "store_scales.npy"))
        if n_base < len(vectors):  # restore the unmerged delta side buffer
            mode, idx.retrain_mode = idx.retrain_mode, "off"
            idx.add(vectors[n_base:], meta["ids"][n_base:])
            idx.retrain_mode = mode
        return idx
