"""Spherical k-means over unit vectors (sem_group_by clustering stage and
the IVF coarse quantizer: `repro.index.ivf_index`)."""
from __future__ import annotations

import numpy as np


def kmeans(vectors: np.ndarray, k: int, *, iters: int = 25, seed: int = 0
           ) -> tuple[np.ndarray, np.ndarray]:
    """-> (centers [k, d] unit vectors, assignment [n])."""
    x = np.asarray(vectors, np.float32)
    n = len(x)
    k = min(k, n)
    rng = np.random.default_rng(seed)

    # k-means++ style init on cosine distance
    centers = [x[rng.integers(n)]]
    for _ in range(1, k):
        d = 1.0 - np.max(np.stack([x @ c for c in centers], 1), axis=1)
        d = np.clip(d, 1e-9, None) ** 2
        centers.append(x[rng.choice(n, p=d / d.sum())])
    c = np.stack(centers)

    assign = np.full(n, -1, np.int64)  # sentinel: nothing assigned yet
    for it in range(iters):
        sims = x @ c.T
        new_assign = np.argmax(sims, axis=1)
        if it > 0 and np.array_equal(new_assign, assign):
            break
        assign = new_assign
        reseeded: set[int] = set()
        for j in range(k):
            m = assign == j
            if m.any():
                v = x[m].mean(axis=0)
                c[j] = v / max(np.linalg.norm(v), 1e-9)
            else:  # re-seed empty cluster at the worst-assigned point
                worst_order = np.argsort(np.max(x @ c.T, axis=1))
                # two empty clusters in one sweep must not grab the same point
                pick = next((int(w) for w in worst_order if int(w) not in reseeded),
                            int(worst_order[0]))
                reseeded.add(pick)
                c[j] = x[pick]
    return c, assign
