"""Symmetric per-vector int8 scalar quantization for IVF tile stores.

The compression behind ``IVFIndex(quantize="int8")``: every corpus vector
``v`` is stored as ``q = round(v / s)`` with its own scale ``s = absmax(v) /
127`` (one f32 per vector, kept in a side array shaped like the tile's lane
axis), so a scanned vector costs ``d + 4`` bytes instead of ``4 * d`` —
~3.9x fewer bytes at d=64 streamed through the cluster-scan hot loop.
Scores dequantize *inside* the scan as one per-lane multiply after the MXU
pass (``(q_f32 @ qv^T) * s``; `repro.kernels.ivf_scan_q`), and the exact
fp32 rerank on top (`IVFIndex._exact_rerank`) restores the measured
recall@k contract.

Everything here is pure numpy — this module is the *reference* the Pallas
kernel and jnp contract (`repro.kernels.ref.ivf_search_q_ref`) must match:

  * per-element round-trip error is bounded by ``s / 2 = absmax / 254``
    (tests/test_quant.py asserts it);
  * an all-zero vector has no meaningful scale — its scale pins to 1.0 so
    quantize/dequantize never divides by zero and the row round-trips to
    exact zeros (padding lanes in the tile store are all-zero by
    construction, so this guard runs on every tile).
"""
from __future__ import annotations

import numpy as np

INT8_MAX = 127          # symmetric range [-127, 127]; -128 stays unused
SCALE_BYTES = 4         # one f32 scale per stored vector


def bytes_per_vector(dim: int, quantize: str = "none") -> float:
    """HBM bytes one scanned corpus vector streams: ``4*d`` at fp32,
    ``d + 4`` (int8 payload + its f32 scale) when quantized."""
    if quantize == "none":
        return 4.0 * dim
    if quantize == "int8":
        return 1.0 * dim + SCALE_BYTES
    raise ValueError(f"quantize={quantize!r} (expected 'none'|'int8')")


def quantize_rows(vectors: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """[n, d] f32 -> (q int8 [n, d], scales f32 [n]).

    Symmetric per-vector: ``scale = absmax / 127``; a zero-norm row (absmax
    == 0, e.g. tile padding) pins its scale to 1.0 — no divide-by-zero, and
    the row dequantizes to exact zeros."""
    v = np.atleast_2d(np.asarray(vectors, np.float32))
    absmax = np.max(np.abs(v), axis=-1) if v.size else np.zeros(len(v))
    scales = np.where(absmax > 0, absmax / INT8_MAX, 1.0).astype(np.float32)
    q = np.clip(np.rint(v / scales[:, None]), -INT8_MAX, INT8_MAX)
    return q.astype(np.int8), scales


def quantize_tiles(store: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Padded IVF tile store [kc, L, d] f32 -> (q int8 [kc, L, d],
    scales f32 [kc, L]).  Padding rows are all-zero, so the zero-norm guard
    gives them scale 1.0 / payload 0 (they are masked out of scores anyway)."""
    kc, L, d = store.shape
    q, scales = quantize_rows(store.reshape(kc * L, d))
    return q.reshape(kc, L, d), scales.reshape(kc, L)


def dequantize_rows(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_rows`: [..., d] int8 * [...] -> f32."""
    return q.astype(np.float32) * np.asarray(scales, np.float32)[..., None]


def quantized_scores(queries: np.ndarray, q: np.ndarray,
                     scales: np.ndarray) -> np.ndarray:
    """Fused dequantize+score, the numerics the kernel implements:
    queries [nq, d] f32 x (q [n, d] int8, scales [n]) -> [nq, n] f32.
    The per-vector scale factors out of the dot product, so dequantization
    is one multiply on the score plane, not ``n * d`` multiplies on the
    payload."""
    qf = np.asarray(queries, np.float32)
    return (qf @ q.astype(np.float32).T) * np.asarray(scales, np.float32)[None, :]
