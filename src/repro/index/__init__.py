"""Retrieval layer: one RetrievalBackend interface, two implementations.

    build_index(vectors, kind="exact"|"ivf"|"auto")   construction
    load_index(path)                                  persistence dispatch
    choose_backend(n_corpus, n_queries, ...)          shared cost model
    choose_retrieval_config(...)                      + tile precision choice

`VectorIndex` is the exact gold reference; `IVFIndex` prunes with spherical
k-means inverted lists and a Pallas cluster-scan kernel (see
`repro.kernels.ivf_scan`).  ``IVFIndex(quantize="int8")`` stores the tiles
as symmetric per-vector int8 (`repro.index.quant`), scans them with the
fused dequantize+score kernel (`repro.kernels.ivf_scan_q`), and exact-
reranks in fp32.  All similarity consumers — sem_search, sem_sim_join, the
join sim-prefilter, sem_group_by center scoring, sem_topk pivot selection —
go through this interface.
"""
from repro.index.backend import (RetrievalBackend, build_index, choose_backend,
                                 choose_retrieval_config, choose_shards,
                                 corpus_fingerprint, embedder_key, load_index,
                                 nprobe_for_recall, retrieval_costs)
from repro.index.ivf_index import IVFIndex
from repro.index.kmeans import kmeans
from repro.index.quant import (bytes_per_vector, dequantize_rows,
                               quantize_rows, quantize_tiles,
                               quantized_scores)
from repro.index.vector_index import VectorIndex

__all__ = [
    "IVFIndex", "RetrievalBackend", "VectorIndex", "build_index",
    "bytes_per_vector", "choose_backend", "choose_retrieval_config",
    "choose_shards", "corpus_fingerprint", "dequantize_rows", "embedder_key",
    "kmeans", "load_index", "nprobe_for_recall", "quantize_rows",
    "quantize_tiles", "quantized_scores", "retrieval_costs",
]
