"""Quantile calibration of proxy scores (§3.1/§3.2: 're-scaling by the
quantiles over all generated log-probabilities / similarity scores')."""
from __future__ import annotations

import numpy as np


def quantile_calibrate(scores) -> np.ndarray:
    """Map raw scores to their empirical quantile rank in (0, 1].

    Rank-based calibration makes thresholds comparable across proxies with
    arbitrary score scales (log-probs vs cosine similarities)."""
    s = np.asarray(scores, float).ravel()
    order = np.argsort(np.argsort(s, kind="stable"), kind="stable")
    return ((order + 1.0) / len(s)).reshape(np.shape(scores))
