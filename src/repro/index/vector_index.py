"""Exact brute-force vector index (the FAISS flat analogue, §4: sem_index).

The gold RetrievalBackend: scores the full corpus per query.  Embeddings are
unit vectors; scores are inner products computed with the Pallas similarity
kernel on TPU (`repro.kernels.similarity`) and its jnp reference elsewhere.
Indices persist to disk (sem_index / load_sem_index).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.index.backend import RetrievalBackend


def _similarity(queries: np.ndarray, corpus: np.ndarray) -> np.ndarray:
    from repro.kernels import ops as kops
    return kops.similarity(queries, corpus)


class VectorIndex(RetrievalBackend):
    kind = "exact"

    def __init__(self, vectors: np.ndarray, ids: list | None = None, *,
                 shards: int | None = None):
        """``shards`` > 1 routes searches through the device-sharded scan
        (``ops.sharded_search``: corpus rows split across the mesh, per-shard
        top-k merged on host) — result-identical to the single-device scan,
        with per-device work cut to ``n/shards`` rows per query."""
        super().__init__(vectors, ids)
        self.shards = int(shards) if shards and shards > 1 else None

    def search(self, queries: np.ndarray, k: int, *, max_pos: int | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """-> (scores [nq, k], indices [nq, k]) by inner product.

        ``max_pos`` bounds results to positions < max_pos — the snapshot
        cutoff for version-pinned queries over a shared stream index that a
        concurrent commit may have grown mid-query (positions are
        append-ordered, so the cutoff is a prefix)."""
        if self.shards and self.shards >= 2 and max_pos is None \
                and len(self.vectors) >= 2 * self.shards and len(queries):
            return self._search_sharded(np.asarray(queries, np.float32), k)
        sims = _similarity(np.asarray(queries, np.float32), self.vectors)
        if max_pos is not None and max_pos < sims.shape[1]:
            sims = sims[:, :max_pos]
        k = min(k, sims.shape[1])
        part = np.argpartition(-sims, k - 1, axis=1)[:, :k]
        psims = np.take_along_axis(sims, part, axis=1)
        order = np.argsort(-psims, axis=1)
        idx = np.take_along_axis(part, order, axis=1)
        d = self.vectors.shape[1] if self.vectors.ndim == 2 else 0
        self.last_stats = {"index": self.kind,
                           "scored_vectors": int(sims.shape[0] * sims.shape[1]),
                           "probed_clusters": 0, "quantize": "none",
                           "scanned_bytes": int(sims.shape[0] * sims.shape[1]
                                                * 4 * d)}
        return np.take_along_axis(sims, idx, axis=1), idx

    def _search_sharded(self, queries: np.ndarray, k: int
                        ) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops as kops
        with self._mut:  # consistent snapshot vs concurrent add()
            vectors = self.vectors
        scores, idx = kops.sharded_search(queries, vectors, k,
                                          shards=self.shards)
        nq, nc = len(queries), len(vectors)
        # the dispatch may clamp to the device count: report the split that
        # actually ran, not the requested layout
        eff = kops.effective_shards(self.shards)
        d = vectors.shape[1] if vectors.ndim == 2 else 0
        self.last_stats = {
            "index": self.kind, "scored_vectors": int(nq * nc),
            "probed_clusters": 0, "shards": eff, "quantize": "none",
            "scanned_bytes": int(nq * nc * 4 * d),
            "scored_vectors_per_shard": int(nq * (-(-nc // max(eff, 1))))}
        return scores, idx

    def pairwise(self, queries: np.ndarray) -> np.ndarray:
        return _similarity(np.asarray(queries, np.float32), self.vectors)

    def describe(self) -> dict:
        out = super().describe()
        if self.shards:
            out["shards"] = self.shards
        return out

    # -- persistence (sem_index / load_sem_index) -------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "vectors.npy"), self.vectors)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump({"kind": self.kind, "ids": self.ids,
                       "dim": int(self.vectors.shape[1]),
                       "shards": self.shards}, f)

    @classmethod
    def load(cls, path: str) -> "VectorIndex":
        vectors = np.load(os.path.join(path, "vectors.npy"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return cls(vectors, meta["ids"], shards=meta.get("shards"))
