"""Deterministic, resumable, shard-aware token pipeline.

Sources:
  * SyntheticSource — seeded token streams (markov-ish bytes) for substrate
    tests and the train example,
  * TextFileSource — newline-delimited UTF-8 documents, byte-tokenized.

Documents are packed into fixed-length sequences (cross-doc packing with EOS
separators, labels = next token).  Batches are a pure function of
(step, shard_id, num_shards, seed) so a restart at step N reproduces the
exact stream without replaying N steps, and every data-parallel host pulls
disjoint data — the standard large-run determinism/resume contract.

``Prefetcher`` overlaps host-side batch assembly with device compute and
implements a straggler guard: if a batch misses its deadline the prefetch
thread is abandoned and the batch is rebuilt synchronously (on a cluster:
re-fetch from a healthy storage replica).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

import numpy as np

from repro.data.tokenizer import TOKENIZER


class SyntheticSource:
    """Deterministic pseudo-text token documents."""

    def __init__(self, seed: int = 0, mean_len: int = 512):
        self.seed = seed
        self.mean_len = mean_len

    def doc_tokens(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, doc_id))
        n = int(rng.integers(self.mean_len // 2, self.mean_len * 2))
        # byte-range tokens with local structure (random walk over bytes)
        steps = rng.integers(-3, 4, n)
        toks = np.cumsum(steps) % 96 + 32
        return toks.astype(np.int32)


class TextFileSource:
    def __init__(self, path: str):
        with open(path, encoding="utf-8") as f:
            self.docs = [l.rstrip("\n") for l in f if l.strip()]

    def doc_tokens(self, doc_id: int) -> np.ndarray:
        text = self.docs[doc_id % len(self.docs)]
        return np.asarray(TOKENIZER.encode(text, bos=False), np.int32)


def packed_batch(source, step: int, *, batch: int, seq_len: int,
                 shard_id: int = 0, num_shards: int = 1, seed: int = 0) -> dict:
    """Pure function of (step, shard) -> {"tokens": [b,S], "labels": [b,S]}."""
    rows = []
    for b in range(batch):
        stream_id = (step * batch + b) * num_shards + shard_id
        rng = np.random.default_rng((seed, stream_id))
        buf: list[int] = [TOKENIZER.bos_id]
        doc = int(rng.integers(0, 2**31 - 1))
        while len(buf) < seq_len + 1:
            toks = source.doc_tokens(doc)
            buf.extend(toks.tolist())
            buf.append(TOKENIZER.eos_id)
            doc += 1
        arr = np.asarray(buf[: seq_len + 1], np.int32)
        rows.append(arr)
    mat = np.stack(rows)
    return {"tokens": mat[:, :-1], "labels": mat[:, 1:]}


class Prefetcher:
    """Host-side prefetch with a straggler deadline."""

    def __init__(self, make_batch: Callable[[int], dict], *, depth: int = 2,
                 deadline_s: float = 30.0):
        self.make_batch = make_batch
        self.deadline_s = deadline_s
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._next_to_schedule = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self.stragglers = 0

    def start(self, from_step: int = 0) -> "Prefetcher":
        self._next_to_schedule = from_step
        self._thread.start()
        return self

    def _work(self) -> None:
        while not self._stop.is_set():
            step = self._next_to_schedule
            batch = self.make_batch(step)
            self._next_to_schedule += 1
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self, step: int) -> dict:
        try:
            got_step, batch = self.q.get(timeout=self.deadline_s)
            if got_step == step:
                return batch
        except queue.Empty:
            pass
        # straggler path: rebuild deterministically, in-line
        self.stragglers += 1
        return self.make_batch(step)

    def stop(self) -> None:
        self._stop.set()
