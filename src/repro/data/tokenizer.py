"""Byte-level tokenizer with reserved control/label tokens.

No external vocab files are available offline; a byte tokenizer is exact,
reversible, and sufficient for the substrate (the semantic-operator layer
only needs token ids + designated single-token labels for predicate /
comparison prompting, mirroring the paper's True/False log-prob proxies).
"""
from __future__ import annotations

import numpy as np

PAD = 256
BOS = 257
EOS = 258
TRUE = 259   # single-token "True" label (predicate prompts)
FALSE = 260  # single-token "False" label
OPT_A = 261  # pairwise-comparison labels (sem_topk)
OPT_B = 262
SEP = 263

VOCAB_SIZE = 384  # 256 bytes + specials, padded up for alignment

SPECIAL_TEXT = {
    "<pad>": PAD, "<bos>": BOS, "<eos>": EOS,
    "<true>": TRUE, "<false>": FALSE, "<A>": OPT_A, "<B>": OPT_B, "<sep>": SEP,
}
_ID_TO_SPECIAL = {v: k for k, v in SPECIAL_TEXT.items()}


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id, bos_id, eos_id = PAD, BOS, EOS
    true_id, false_id, a_id, b_id, sep_id = TRUE, FALSE, OPT_A, OPT_B, SEP

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        out: list[str] = []
        buf: list[int] = []
        for t in np.asarray(ids).tolist():
            if t < 256:
                buf.append(t)
            else:
                if buf:
                    out.append(bytes(buf).decode("utf-8", errors="replace"))
                    buf = []
                if t in _ID_TO_SPECIAL and t not in (BOS, PAD):
                    out.append(_ID_TO_SPECIAL[t])
        if buf:
            out.append(bytes(buf).decode("utf-8", errors="replace"))
        return "".join(out)

    def pad_batch(self, seqs: list[list[int]], length: int | None = None) -> np.ndarray:
        length = length or max(len(s) for s in seqs)
        out = np.full((len(seqs), length), PAD, np.int32)
        for i, s in enumerate(seqs):
            out[i, : min(len(s), length)] = s[:length]
        return out


TOKENIZER = ByteTokenizer()
