"""Public jit'd wrappers for the Pallas kernels with implementation dispatch.

    impl="auto"      Pallas on TPU, jnp reference elsewhere (CPU CI)
    impl="pallas"    force compiled Pallas (TPU)
    impl="interpret" Pallas kernel body interpreted on CPU (tests)
    impl="ref"       pure-jnp oracle
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import ivf_scan as _ivf
from repro.kernels import ivf_scan_q as _ivfq
from repro.kernels import ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import similarity as _sim
from repro.obs import trace as _trace

DEFAULT_IMPL = "auto"


@contextlib.contextmanager
def _kernel_span(name: str, mode: str, **attrs):
    """Kernel-dispatch observability, active only under a tracer: a
    ``jax.named_scope`` so the dispatch is labeled in XLA/Perfetto device
    profiles, plus a ``kind="kernel"`` trace span so host-side kernel time
    is attributed to the owning operator span.  Yields the span (None when
    tracing is off — the zero-overhead default path)."""
    if _trace.current_tracer() is None:
        yield None
        return
    with jax.named_scope(f"repro.{name}"):
        with _trace.span(f"kernel/{name}", kind="kernel",
                         impl=mode, **attrs) as sp:
            yield sp


def _ready(out, sp):
    """Under a tracer, block until device work finishes so the enclosing
    kernel span measures compute, not dispatch; untraced calls keep jax's
    async dispatch (the ``np.asarray`` conversions sync anyway)."""
    if sp is not None:
        out = jax.block_until_ready(out)
    return out


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str | None) -> str:
    impl = impl or DEFAULT_IMPL
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str | None = None, **kw):
    mode = _resolve(impl)
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(mode == "interpret"), **kw)


def decode_attention(q, k, v, lens, *, impl: str | None = None, **kw):
    mode = _resolve(impl)
    if mode == "ref":
        return ref.decode_attention_ref(q, k, v, jnp.asarray(lens))
    return _da.decode_attention(q, k, v, lens, interpret=(mode == "interpret"), **kw)


@functools.partial(jax.jit, static_argnames=("normalize",))
def _sim_ref_jit(q, c, normalize=True):
    return ref.similarity_ref(q, c, normalize=normalize)


def similarity(queries, corpus, *, normalize: bool = True,
               impl: str | None = None, **kw) -> np.ndarray:
    mode = _resolve(impl)
    with _kernel_span("similarity", mode, nq=len(queries),
                      nc=len(corpus)) as sp:
        if mode == "ref":
            out = _sim_ref_jit(jnp.asarray(queries), jnp.asarray(corpus),
                               normalize=normalize)
        else:
            out = _sim.similarity(queries, corpus, normalize=normalize,
                                  interpret=(mode == "interpret"), **kw)
        return np.asarray(_ready(out, sp))


def ivf_search(queries, centroids, store, mask, *, nprobe: int,
               block_q: int = 8, impl: str | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
    """Fused IVF retrieval: centroid scoring + per-query top-``nprobe``
    probe selection + masked cluster scan over the padded inverted file.

    -> (scores [nq, block_q*nprobe*L] f32, probe_blocks [nb, block_q*nprobe]);
    masked/padded candidates score ``ref.MASKED_SCORE``."""
    mode = _resolve(impl)
    with _kernel_span("ivf_search", mode, nq=len(queries),
                      nprobe=nprobe) as sp:
        if mode == "ref":
            s, p = ref.ivf_search_ref(jnp.asarray(queries),
                                      jnp.asarray(centroids),
                                      jnp.asarray(store), jnp.asarray(mask),
                                      nprobe=nprobe, block_q=block_q)
        else:
            s, p = _ivf.ivf_search(queries, centroids, store, mask,
                                   nprobe=nprobe, block_q=block_q,
                                   interpret=(mode == "interpret"))
        s = _ready(s, sp)
        return np.asarray(s), np.asarray(p)


def ivf_delta_search(queries, centroids, store, mask, delta_vectors, *,
                     nprobe: int, block_q: int = 8, impl: str | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Delta-aware IVF retrieval: the fused probed-cluster scan
    (:func:`ivf_search` — Pallas on TPU) plus an exact scan of the streaming
    delta side buffer, concatenated along the candidate axis.  The buffer is
    small by construction (the drift detector retrains past the spill
    threshold), so its exact scan rides the plain similarity kernel.

    -> (scores [nq, block_q*nprobe*L + nd] f32, probe_blocks); jnp contract:
    ``ref.ivf_delta_search_ref``."""
    mode = _resolve(impl)
    if mode == "ref":
        s, p = ref.ivf_delta_search_ref(
            jnp.asarray(queries), jnp.asarray(centroids), jnp.asarray(store),
            jnp.asarray(mask), jnp.asarray(delta_vectors),
            nprobe=nprobe, block_q=block_q)
        return np.asarray(s), np.asarray(p)
    s, p = ivf_search(queries, centroids, store, mask, nprobe=nprobe,
                      block_q=block_q, impl=impl)
    ds = similarity(queries, delta_vectors, normalize=True, impl=impl)
    return np.concatenate([s, np.asarray(ds, np.float32)], axis=1), p


def ivf_search_q(queries, centroids, store_q, scales, mask, *, nprobe: int,
                 block_q: int = 8, impl: str | None = None
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Fused *quantized* IVF retrieval: the :func:`ivf_search` pipeline over
    symmetric per-vector int8 tiles (``store_q`` int8 + ``scales`` f32;
    `repro.index.quant`), dequantization fused into the cluster scan as one
    per-lane multiply on the score plane — ``d + 4`` HBM bytes per scanned
    vector instead of ``4 * d``.

    -> (scores [nq, block_q*nprobe*L] f32, probe_blocks); jnp contract:
    ``ref.ivf_search_q_ref``."""
    mode = _resolve(impl)
    with _kernel_span("ivf_search_q", mode, nq=len(queries),
                      nprobe=nprobe) as sp:
        if mode == "ref":
            s, p = ref.ivf_search_q_ref(
                jnp.asarray(queries), jnp.asarray(centroids),
                jnp.asarray(store_q, jnp.int8), jnp.asarray(scales),
                jnp.asarray(mask), nprobe=nprobe, block_q=block_q)
        else:
            s, p = _ivfq.ivf_search_q(queries, centroids, store_q, scales,
                                      mask, nprobe=nprobe, block_q=block_q,
                                      interpret=(mode == "interpret"))
        s = _ready(s, sp)
        return np.asarray(s), np.asarray(p)


def ivf_delta_search_q(queries, centroids, store_q, scales, mask, delta_q,
                       delta_scales, *, nprobe: int, block_q: int = 8,
                       impl: str | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Quantized delta-aware IVF retrieval: the fused quantized probed-
    cluster scan plus a dequantize-fused exact scan of the int8 streaming
    delta side buffer, concatenated along the candidate axis.

    -> (scores [nq, block_q*nprobe*L + nd] f32, probe_blocks); jnp contract:
    ``ref.ivf_delta_search_q_ref``."""
    mode = _resolve(impl)
    if mode == "ref":
        s, p = ref.ivf_delta_search_q_ref(
            jnp.asarray(queries), jnp.asarray(centroids),
            jnp.asarray(store_q, jnp.int8), jnp.asarray(scales),
            jnp.asarray(mask), jnp.asarray(delta_q, jnp.int8),
            jnp.asarray(delta_scales), nprobe=nprobe, block_q=block_q)
        return np.asarray(s), np.asarray(p)
    s, p = ivf_search_q(queries, centroids, store_q, scales, mask,
                        nprobe=nprobe, block_q=block_q, impl=impl)
    from repro.index.quant import quantized_scores
    q = np.asarray(queries, np.float32)
    q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    ds = quantized_scores(q, np.asarray(delta_q), np.asarray(delta_scales))
    return np.concatenate([s, np.asarray(ds, np.float32)], axis=1), p


def _n_devices() -> int:
    try:
        return len(jax.devices())
    except Exception:  # pragma: no cover
        return 1


def _resolve_sharded(impl: str | None, n_shards: int) -> tuple[str, int]:
    """Sharded ops dispatch: ``shard_map`` needs real devices, so "auto"
    takes the shard_map path only when the process actually has more than
    one (clamping the shard count to the device count); otherwise the jnp
    reference *simulates* the shard partitioning with identical numerics —
    which is what keeps single-device CI meaningful."""
    impl = impl or DEFAULT_IMPL
    if impl == "auto":
        impl = "shard_map" if _n_devices() > 1 else "ref"
    if impl in ("pallas", "interpret"):
        impl = "shard_map"
    if impl == "shard_map":
        n_shards = max(1, min(n_shards, _n_devices()))
    return impl, n_shards


def effective_shards(shards: int) -> int:
    """The shard count the auto dispatch will actually run: clamped to the
    device count on the shard_map path, the requested count on the jnp
    simulation path.  Index layers use this so per-shard accounting
    (``scored_vectors_per_shard``) describes the real work split, not the
    requested layout."""
    _, n = _resolve_sharded(None, shards)
    return n


def sharded_search(queries, corpus, k: int, *, shards: int,
                   normalize: bool = True, impl: str | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Device-sharded exact top-k: corpus rows split across ``shards``
    devices via ``shard_map`` (per-shard similarity kernel + local top-k),
    per-shard candidates merged on host.  Lossless — the merged top-k is
    identical to a full exact scan (``ref.sharded_search_ref`` is the jnp
    contract).  -> (scores [nq, k], global idx [nq, k])."""
    mode, shards = _resolve_sharded(impl, shards)
    with _kernel_span("sharded_search", mode, nq=len(queries),
                      nc=len(corpus), shards=shards) as sp:
        if mode == "ref" or shards <= 1:
            s, i = ref.sharded_search_ref(jnp.asarray(queries),
                                          jnp.asarray(corpus), k,
                                          max(shards, 1), normalize=normalize)
            s = _ready(s, sp)
            return np.asarray(s), np.asarray(i, np.int64)
        vals, idx = _sim.sharded_similarity_topk(
            queries, corpus, k, n_shards=shards, normalize=normalize,
            use_pallas=_on_tpu())
        s, i = ref.shard_topk_merge(vals, idx, k)
        s = _ready(s, sp)
        return np.asarray(s), np.asarray(i, np.int64)


def sharded_ivf_search(queries, centroids, store, mask, *, nprobe: int,
                       shards: int, block_q: int = 8, impl: str | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Device-sharded IVF retrieval: cluster tiles partitioned across
    ``shards`` devices, global probe selection, per-device masked scan of
    the locally-owned probed clusters combined with one pmax.  The score
    plane (and thus the downstream top-k) is identical to :func:`ivf_search`
    — sharding redistributes scan work, never results.  jnp contract:
    ``ref.sharded_ivf_search_ref``."""
    mode, shards = _resolve_sharded(impl, shards)
    with _kernel_span("sharded_ivf_search", mode, nq=len(queries),
                      nprobe=nprobe, shards=shards) as sp:
        if mode == "ref" or shards <= 1:
            s, p = ref.sharded_ivf_search_ref(
                jnp.asarray(queries), jnp.asarray(centroids),
                jnp.asarray(store), jnp.asarray(mask), nprobe=nprobe,
                n_shards=max(shards, 1), block_q=block_q)
        else:
            s, p = _ivf.sharded_ivf_search(
                queries, centroids, store, mask, nprobe=nprobe,
                n_shards=shards, block_q=block_q, use_pallas=_on_tpu())
        s = _ready(s, sp)
        return np.asarray(s), np.asarray(p)


def sharded_ivf_search_q(queries, centroids, store_q, scales, mask, *,
                         nprobe: int, shards: int, block_q: int = 8,
                         impl: str | None = None
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Device-sharded quantized IVF retrieval: int8 cluster tiles + their
    scale rows partitioned across ``shards`` devices, global probe
    selection, per-device fused dequantize+scan of the locally-owned probed
    clusters combined with one pmax.  Score plane identical to
    :func:`ivf_search_q` — sharding redistributes scan bytes, never
    results.  jnp contract: ``ref.sharded_ivf_search_q_ref``."""
    mode, shards = _resolve_sharded(impl, shards)
    with _kernel_span("sharded_ivf_search_q", mode, nq=len(queries),
                      nprobe=nprobe, shards=shards) as sp:
        if mode == "ref" or shards <= 1:
            s, p = ref.sharded_ivf_search_q_ref(
                jnp.asarray(queries), jnp.asarray(centroids),
                jnp.asarray(store_q, jnp.int8), jnp.asarray(scales),
                jnp.asarray(mask), nprobe=nprobe, n_shards=max(shards, 1),
                block_q=block_q)
        else:
            s, p = _ivfq.sharded_ivf_search_q(
                queries, centroids, store_q, scales, mask, nprobe=nprobe,
                n_shards=shards, block_q=block_q, use_pallas=_on_tpu())
        s = _ready(s, sp)
        return np.asarray(s), np.asarray(p)


def rmsnorm(x, scale, *, eps: float = 1e-5, impl: str | None = None, **kw):
    mode = _resolve(impl)
    if mode == "ref":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    return _rn.rmsnorm(x, scale, eps=eps, interpret=(mode == "interpret"), **kw)
