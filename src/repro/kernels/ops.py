"""Public jit'd wrappers for the Pallas kernels with implementation dispatch.

    impl="auto"      Pallas on TPU, jnp reference elsewhere (CPU CI)
    impl="pallas"    force compiled Pallas (TPU)
    impl="interpret" Pallas kernel body interpreted on CPU (tests)
    impl="ref"       pure-jnp oracle
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import ivf_scan as _ivf
from repro.kernels import ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import similarity as _sim

DEFAULT_IMPL = "auto"


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str | None) -> str:
    impl = impl or DEFAULT_IMPL
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str | None = None, **kw):
    mode = _resolve(impl)
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(mode == "interpret"), **kw)


def decode_attention(q, k, v, lens, *, impl: str | None = None, **kw):
    mode = _resolve(impl)
    if mode == "ref":
        return ref.decode_attention_ref(q, k, v, jnp.asarray(lens))
    return _da.decode_attention(q, k, v, lens, interpret=(mode == "interpret"), **kw)


@functools.partial(jax.jit, static_argnames=("normalize",))
def _sim_ref_jit(q, c, normalize=True):
    return ref.similarity_ref(q, c, normalize=normalize)


def similarity(queries, corpus, *, normalize: bool = True,
               impl: str | None = None, **kw) -> np.ndarray:
    mode = _resolve(impl)
    if mode == "ref":
        return np.asarray(_sim_ref_jit(jnp.asarray(queries), jnp.asarray(corpus),
                                       normalize=normalize))
    return np.asarray(_sim.similarity(queries, corpus, normalize=normalize,
                                      interpret=(mode == "interpret"), **kw))


def ivf_search(queries, centroids, store, mask, *, nprobe: int,
               block_q: int = 8, impl: str | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
    """Fused IVF retrieval: centroid scoring + per-query top-``nprobe``
    probe selection + masked cluster scan over the padded inverted file.

    -> (scores [nq, block_q*nprobe*L] f32, probe_blocks [nb, block_q*nprobe]);
    masked/padded candidates score ``ref.MASKED_SCORE``."""
    mode = _resolve(impl)
    if mode == "ref":
        s, p = ref.ivf_search_ref(jnp.asarray(queries), jnp.asarray(centroids),
                                  jnp.asarray(store), jnp.asarray(mask),
                                  nprobe=nprobe, block_q=block_q)
    else:
        s, p = _ivf.ivf_search(queries, centroids, store, mask, nprobe=nprobe,
                               block_q=block_q, interpret=(mode == "interpret"))
    return np.asarray(s), np.asarray(p)


def ivf_delta_search(queries, centroids, store, mask, delta_vectors, *,
                     nprobe: int, block_q: int = 8, impl: str | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Delta-aware IVF retrieval: the fused probed-cluster scan
    (:func:`ivf_search` — Pallas on TPU) plus an exact scan of the streaming
    delta side buffer, concatenated along the candidate axis.  The buffer is
    small by construction (the drift detector retrains past the spill
    threshold), so its exact scan rides the plain similarity kernel.

    -> (scores [nq, block_q*nprobe*L + nd] f32, probe_blocks); jnp contract:
    ``ref.ivf_delta_search_ref``."""
    mode = _resolve(impl)
    if mode == "ref":
        s, p = ref.ivf_delta_search_ref(
            jnp.asarray(queries), jnp.asarray(centroids), jnp.asarray(store),
            jnp.asarray(mask), jnp.asarray(delta_vectors),
            nprobe=nprobe, block_q=block_q)
        return np.asarray(s), np.asarray(p)
    s, p = ivf_search(queries, centroids, store, mask, nprobe=nprobe,
                      block_q=block_q, impl=impl)
    ds = similarity(queries, delta_vectors, normalize=True, impl=impl)
    return np.concatenate([s, np.asarray(ds, np.float32)], axis=1), p


def rmsnorm(x, scale, *, eps: float = 1e-5, impl: str | None = None, **kw):
    mode = _resolve(impl)
    if mode == "ref":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    return _rn.rmsnorm(x, scale, eps=eps, interpret=(mode == "interpret"), **kw)
