"""Pallas TPU flash attention (GQA-aware, causal / sliding-window).

TPU blocking discipline: grid (batch, q-heads, q-blocks, kv-blocks) with the
kv-block dimension innermost — TPU grids execute sequentially per core, so
the online-softmax state (row-max m, row-sum l, output accumulator) lives in
VMEM scratch across kv-block steps.  Block sizes default to 128 (MXU tile);
GQA is expressed in the k/v BlockSpec index maps (q-head h reads kv-head
h // group) so the repeated KV is never materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_kv: int, seq_kv: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                # [bq, hd]
    k = k_ref[0, 0]                                # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_kv
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q:[B,Sq,H,hd], k/v:[B,Sk,Hk,hd] -> [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    sk, hk = k.shape[1], k.shape[2]
    group = h // hk
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    # pad seqs up to block multiples (masked out inside the kernel)
    pq = (-sq) % bq
    pk = (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk
    n_q, n_kv = sq_p // bq, sk_p // bk

    qT = q.transpose(0, 2, 1, 3)  # [B,H,Sq,hd]
    kT = k.transpose(0, 2, 1, 3)  # [B,Hk,Sk,hd]
    vT = v.transpose(0, 2, 1, 3)

    grid = (b, h, n_q, n_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (hd ** 0.5), causal=causal,
                          window=window, bq=bq, bk=bk, n_kv=n_kv, seq_kv=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qT, kT, vT)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :sq] if pq else out
