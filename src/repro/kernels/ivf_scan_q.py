"""Pallas TPU quantized IVF cluster scan: fused dequantize+score.

The int8 sibling of `repro.kernels.ivf_scan`: the same scalar-prefetched
masked gather-scan over padded per-cluster tiles — same static MXU grid
(query blocks x probe slots), same probe selection, same ``MASKED_SCORE``
padding discipline — but the tiles ride in as symmetric per-vector int8
(``store_q [kc, L, d]`` int8 + ``scales [kc, L]`` f32;
`repro.index.quant`), cutting the HBM bytes the hot loop streams per
vector from ``4*d`` to ``d + 4``.

Dequantization fuses into the scan: the per-vector scale factors out of the
inner product, so the kernel upcasts the int8 tile for one MXU pass and
multiplies the *score plane* by the tile's scale row — d multiplies per
vector become 1, and no f32 copy of the tile ever materializes.

`repro.kernels.ref.ivf_search_q_ref` is the pure-jnp contract (CPU CI);
``interpret=True`` runs this kernel body under the Pallas interpreter.
The recall story lives a layer up: `IVFIndex(quantize="int8")` exact-reranks
the top ``rerank_factor*k`` quantized candidates in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MASKED_SCORE, _unitize, ivf_probes, pad_queries


def _scan_kernel_q(p_ref, q_ref, v_ref, s_ref, m_ref, o_ref, *,
                   normalize: bool):
    del p_ref  # probe ids are consumed by the index_maps, not the body
    q = q_ref[...].astype(jnp.float32)                      # [bq, d]
    if normalize:
        q = q * jax.lax.rsqrt(jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))
    v = v_ref[0].astype(jnp.float32)                        # [L, d] int8 -> f32
    s = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, L]
    s = s * s_ref[0][None, :]           # fused dequantize: per-vector scale
    o_ref[...] = jnp.where(m_ref[0][None, :] > 0, s, MASKED_SCORE)


def cluster_scan_q(queries, store_q, scales, mask, probe_blocks, *,
                   block_q: int = 8, normalize: bool = True,
                   interpret: bool = False):
    """queries [nb*bq, d], store_q [kc, L, d] int8, scales [kc, L] f32,
    mask [kc, L], probe_blocks [nb, slots] int32 -> scores [nb*bq, slots*L]
    f32 (padding slots = MASKED_SCORE)."""
    nq, d = queries.shape
    _, L, _ = store_q.shape
    nb, slots = probe_blocks.shape
    assert nq == nb * block_q, "queries must be pre-padded to full blocks"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, slots),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j, p: (i, 0)),
            pl.BlockSpec((1, L, d), lambda i, j, p: (p[i, j], 0, 0)),
            pl.BlockSpec((1, L), lambda i, j, p: (p[i, j], 0)),
            pl.BlockSpec((1, L), lambda i, j, p: (p[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((block_q, L), lambda i, j, p: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_scan_kernel_q, normalize=normalize),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nq, slots * L), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(probe_blocks, jnp.int32), jnp.asarray(queries),
      jnp.asarray(store_q, jnp.int8), jnp.asarray(scales, jnp.float32),
      jnp.asarray(mask))


def ivf_search_q(queries, centroids, store_q, scales, mask, *, nprobe: int,
                 block_q: int = 8, interpret: bool = False):
    """Fused quantized IVF search: centroid scoring + per-query top-``nprobe``
    probe selection (both fp32 — centroids are tiny) + quantized cluster
    scan, no host round trip between stages.

    -> (scores [nq, bq*nprobe*L], probe_blocks [nb, bq*nprobe]); row i's
    candidate j came from cluster probe_blocks[i // bq, j // L], slot j % L.
    """
    q, nb = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)  # same normalization as the jnp reference, by definition
    probe_blocks = ivf_probes(q, jnp.asarray(centroids), nprobe, block_q)
    scores = cluster_scan_q(q, store_q, scales, mask, probe_blocks,
                            block_q=block_q, normalize=False,
                            interpret=interpret)
    return scores[: len(queries)], probe_blocks


# ---------------------------------------------------------------------------
# Device-sharded quantized scan (shard_map over the cluster axis)
# ---------------------------------------------------------------------------


def sharded_ivf_search_q(queries, centroids, store_q, scales, mask, *,
                         nprobe: int, n_shards: int, block_q: int = 8,
                         mesh=None, interpret: bool = False,
                         use_pallas: bool = False):
    """Device-sharded quantized IVF search: identical sharding discipline to
    ``ivf_scan.sharded_ivf_search`` (int8 tiles + their scale rows
    partitioned across ``n_shards`` devices along the cluster axis, global
    probe selection, each device scans only the probed clusters it owns,
    per-device planes combine with one ``pmax``) — the combined plane is
    identical to the unsharded :func:`ivf_search_q` while per-device *bytes*
    drop to the local probed clusters' int8 tiles.  jnp contract:
    ``repro.kernels.ref.sharded_ivf_search_q_ref``."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels.ref import ivf_scan_q_ref
    from repro.kernels.similarity import shard_mesh, shard_map

    q, nb = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)
    probe_blocks = ivf_probes(q, jnp.asarray(centroids), nprobe, block_q)
    kc, L, d = store_q.shape
    mesh = mesh if mesh is not None else shard_mesh(n_shards)
    local = max(1, -(-kc // n_shards))
    pad = n_shards * local - kc
    st = jnp.asarray(store_q, jnp.int8)
    sc = jnp.asarray(scales, jnp.float32)
    mk = jnp.asarray(mask)
    if pad:
        # equal tiles per device; padded clusters are never probed (probe
        # ids are < kc) and their mask is zero anyway
        st = jnp.concatenate([st, jnp.zeros((pad, L, d), st.dtype)])
        sc = jnp.concatenate([sc, jnp.ones((pad, L), sc.dtype)])
        mk = jnp.concatenate([mk, jnp.zeros((pad, L), mk.dtype)])

    def body(q, p, st_local, sc_local, mk_local):
        offset = jax.lax.axis_index("shard") * st_local.shape[0]
        local_p = p - offset
        in_range = (local_p >= 0) & (local_p < st_local.shape[0])
        safe = jnp.where(in_range, local_p, 0).astype(jnp.int32)
        if use_pallas:
            s = cluster_scan_q(q, st_local, sc_local, mk_local, safe,
                               block_q=block_q, normalize=False,
                               interpret=interpret)
        else:
            s = ivf_scan_q_ref(q, st_local, sc_local, mk_local, safe,
                               block_q=block_q, normalize=False)
        keep = jnp.repeat(jnp.repeat(in_range, L, axis=1), block_q, axis=0)
        s = jnp.where(keep, s, MASKED_SCORE)
        return jax.lax.pmax(s, "shard")

    scores = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P("shard", None, None), P("shard", None),
                  P("shard", None)),
        out_specs=P(),
        check_rep=False)(q, probe_blocks, st, sc, mk)
    return scores[: len(queries)], probe_blocks
