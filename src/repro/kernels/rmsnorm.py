"""Pallas TPU fused RMSNorm (row-blocked, f32 statistics in VMEM).

Fuses square/mean/rsqrt/scale into one HBM pass — RMSNorm is called twice per
transformer layer and is pure memory traffic on the XLA path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: [..., d], scale: [d] -> same shape/dtype as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    br = min(block_rows, n)
    pr = (-n) % br
    if pr:
        x2 = jnp.pad(x2, ((0, pr), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((n + pr) // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pr, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:n].reshape(orig_shape)
