"""Pallas TPU flash-decoding: one query token against a long KV cache.

Decode attention is memory-bandwidth-bound (the entire KV cache streams
through once per step); the kernel tiles the cache's sequence dimension
across grid steps (VMEM-resident [bk, hd] tiles), carries the online-softmax
state in scratch, and masks by the per-sequence cache length (read from a
[B] lengths vector).  Grid: (batch, q-heads, kv-blocks), kv innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bk: int, n_kv: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                  # [1, hd]
    k = k_ref[0, 0]                                  # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [1, bk]
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = k_pos <= lens_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, lens, *, block_k: int = 256, interpret: bool = False):
    """q:[B,1,H,hd], k/v:[B,S,Hk,hd], lens:[B] -> [B,1,H,hd].

    Attends to cache positions 0..lens[b] inclusive."""
    b, _, h, hd = q.shape
    sk, hk = k.shape[1], k.shape[2]
    group = h // hk
    bk = min(block_k, sk)
    pk = (-sk) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    n_kv = (sk + pk) // bk

    qT = q.transpose(0, 2, 1, 3)     # [B,H,1,hd]
    kT = k.transpose(0, 2, 1, 3)     # [B,Hk,S,hd]
    vT = v.transpose(0, 2, 1, 3)
    lens = jnp.asarray(lens, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (hd ** 0.5), bk=bk, n_kv=n_kv),
        grid=(b, h, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, ik: (b_,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, hd), lambda b_, h_, ik: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, ik: (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, ik: (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qT, kT, vT)
    return out.transpose(0, 2, 1, 3)
