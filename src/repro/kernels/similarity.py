"""Pallas TPU batched similarity: fused L2-normalize + MXU-tiled inner
products — the vector-search hot loop behind sem_search / sem_sim_join /
sem_join's sim-filter proxy (the FAISS-GPU analogue, TPU-native).

Grid (q-blocks, c-blocks); the full feature dim d rides inside the block
(embedding dims are <= a few thousand — one VMEM tile).  Norms are fused so
raw (un-normalized) embeddings never round-trip through HBM twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, c_ref, o_ref, *, normalize: bool):
    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    if normalize:
        q = q * jax.lax.rsqrt(jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))
        c = c * jax.lax.rsqrt(jnp.maximum(jnp.sum(c * c, -1, keepdims=True), 1e-18))
    o_ref[...] = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def similarity(queries, corpus, *, normalize: bool = True,
               block_q: int = 256, block_c: int = 256, interpret: bool = False):
    """queries:[nq,d], corpus:[nc,d] -> [nq,nc] f32 scores."""
    nq, d = queries.shape
    nc = corpus.shape[0]
    bq = min(block_q, nq)
    bc = min(block_c, nc)
    pq = (-nq) % bq
    pc = (-nc) % bc
    q = jnp.pad(jnp.asarray(queries), ((0, pq), (0, 0))) if pq else jnp.asarray(queries)
    c = jnp.pad(jnp.asarray(corpus), ((0, pc), (0, 0))) if pc else jnp.asarray(corpus)

    out = pl.pallas_call(
        functools.partial(_kernel, normalize=normalize),
        grid=((nq + pq) // bq, (nc + pc) // bc),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq + pq, nc + pc), jnp.float32),
        interpret=interpret,
    )(q, c)
    return out[:nq, :nc]
