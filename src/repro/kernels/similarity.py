"""Pallas TPU batched similarity: fused L2-normalize + MXU-tiled inner
products — the vector-search hot loop behind sem_search / sem_sim_join /
sem_join's sim-filter proxy (the FAISS-GPU analogue, TPU-native).

Grid (q-blocks, c-blocks); the full feature dim d rides inside the block
(embedding dims are <= a few thousand — one VMEM tile).  Norms are fused so
raw (un-normalized) embeddings never round-trip through HBM twice.

``sharded_similarity_topk`` is the device-parallel wrapper: the corpus is
row-sharded across a 1-D mesh with ``shard_map``, each device scores its
local tile (this kernel on TPU, its jnp math elsewhere) and keeps a local
top-k, and the per-shard candidate lists are merged on host
(`repro.kernels.ref.shard_topk_merge`).  jnp contract:
``ref.sharded_search_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map
except ImportError:  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map

from repro.kernels.ref import MASKED_SCORE, _unitize, pad_corpus_shards


def _kernel(q_ref, c_ref, o_ref, *, normalize: bool):
    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    if normalize:
        q = q * jax.lax.rsqrt(jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))
        c = c * jax.lax.rsqrt(jnp.maximum(jnp.sum(c * c, -1, keepdims=True), 1e-18))
    o_ref[...] = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def similarity(queries, corpus, *, normalize: bool = True,
               block_q: int = 256, block_c: int = 256, interpret: bool = False):
    """queries:[nq,d], corpus:[nc,d] -> [nq,nc] f32 scores."""
    nq, d = queries.shape
    nc = corpus.shape[0]
    bq = min(block_q, nq)
    bc = min(block_c, nc)
    pq = (-nq) % bq
    pc = (-nc) % bc
    q = jnp.pad(jnp.asarray(queries), ((0, pq), (0, 0))) if pq else jnp.asarray(queries)
    c = jnp.pad(jnp.asarray(corpus), ((0, pc), (0, 0))) if pc else jnp.asarray(corpus)

    out = pl.pallas_call(
        functools.partial(_kernel, normalize=normalize),
        grid=((nq + pq) // bq, (nc + pc) // bc),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq + pq, nc + pc), jnp.float32),
        interpret=interpret,
    )(q, c)
    return out[:nq, :nc]


def shard_mesh(n_shards: int, *, devices=None) -> Mesh:
    """1-D retrieval mesh over the first ``n_shards`` devices."""
    devices = list(devices if devices is not None else jax.devices())[:n_shards]
    return Mesh(np.asarray(devices), ("shard",))


def sharded_similarity_topk(queries, corpus, k: int, *, n_shards: int,
                            mesh: Mesh | None = None, normalize: bool = True,
                            interpret: bool = False, use_pallas: bool = False):
    """Device-sharded exact top-k: corpus rows split across ``n_shards``
    devices; each shard scores its tile and keeps ``min(k, local)`` local
    winners (global row ids reconstructed from ``axis_index``); the caller
    merges the [nq, n_shards*k_l] candidates (``ref.shard_topk_merge``).

    ``use_pallas`` runs the MXU similarity kernel per shard (TPU);
    otherwise the shard body is the kernel's jnp math (CPU multi-device).
    -> (scores [nq, n_shards*k_l], global idx [nq, n_shards*k_l]).
    """
    mesh = mesh if mesh is not None else shard_mesh(n_shards)
    q = jnp.asarray(queries, jnp.float32)
    c = jnp.asarray(corpus, jnp.float32)
    if normalize:  # normalize outside: rows are independent, shards agree
        q = _unitize(q)  # the reference's normalization, by definition
        c = _unitize(c)
    c, valid, local = pad_corpus_shards(c, n_shards)
    k_l = min(k, local)

    def body(q, c_local, v_local):
        if use_pallas:
            s = similarity(q, c_local, normalize=False, interpret=interpret)
        else:
            s = jax.lax.dot_general(q, c_local, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        s = jnp.where(v_local[None, :] > 0, s, MASKED_SCORE)
        vals, loc = jax.lax.top_k(s, k_l)
        offset = jax.lax.axis_index("shard") * c_local.shape[0]
        return vals, (loc + offset).astype(jnp.int32)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("shard", None), P("shard")),
        out_specs=(P(None, "shard"), P(None, "shard")),
        check_rep=False)(q, c, valid)
