"""Pallas TPU IVF cluster scan: the ANN hot loop behind `IVFIndex.search`.

One fused pipeline per search:

  1. centroid scoring  — queries x coarse-quantizer centroids (one MXU pass);
  2. probe selection   — per-query top-``nprobe`` clusters (`jax.lax.top_k`);
  3. cluster scan      — the hand-written kernel below: a masked gather-scan
     over *only the probed clusters'* vectors.

The inverted file is laid out as padded per-cluster tiles ``store [kc, L, d]``
(`L` = max cluster size rounded up to the lane width) with a validity mask
``mask [kc, L]``, so the MXU grid stays static: grid = (query-blocks, probe
slots), and the probed cluster id rides in as a *scalar-prefetched* index —
the BlockSpec index_map gathers exactly that cluster's tile from HBM, scores
it against the query block on the MXU, and masks the padding lanes to -inf.
Work is O(sum of probed cluster sizes), not O(corpus).

Probe slots are per-query: a block of ``block_q`` queries scans the
concatenation of its queries' top-``nprobe`` lists (every query is
guaranteed its own best clusters; blockmates' clusters come along free since
the MXU scores the whole query block per tile anyway).

`repro.kernels.ref.ivf_search_ref` is the pure-jnp reference (CPU CI), and
`interpret=True` runs this kernel body under the Pallas interpreter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MASKED_SCORE, _unitize, ivf_probes, pad_queries


def _scan_kernel(p_ref, q_ref, v_ref, m_ref, o_ref, *, normalize: bool):
    del p_ref  # probe ids are consumed by the index_maps, not the body
    q = q_ref[...].astype(jnp.float32)                      # [bq, d]
    if normalize:
        q = q * jax.lax.rsqrt(jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))
    v = v_ref[0].astype(jnp.float32)                        # [L, d]
    s = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, L]
    o_ref[...] = jnp.where(m_ref[0][None, :] > 0, s, MASKED_SCORE)


def cluster_scan(queries, store, mask, probe_blocks, *, block_q: int = 8,
                 normalize: bool = True, interpret: bool = False):
    """queries [nb*bq, d], store [kc, L, d], mask [kc, L],
    probe_blocks [nb, slots] int32 -> scores [nb*bq, slots*L] f32
    (padding slots = MASKED_SCORE)."""
    nq, d = queries.shape
    _, L, _ = store.shape
    nb, slots = probe_blocks.shape
    assert nq == nb * block_q, "queries must be pre-padded to full blocks"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, slots),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j, p: (i, 0)),
            pl.BlockSpec((1, L, d), lambda i, j, p: (p[i, j], 0, 0)),
            pl.BlockSpec((1, L), lambda i, j, p: (p[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((block_q, L), lambda i, j, p: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_scan_kernel, normalize=normalize),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nq, slots * L), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(probe_blocks, jnp.int32), jnp.asarray(queries),
      jnp.asarray(store), jnp.asarray(mask))


def ivf_search(queries, centroids, store, mask, *, nprobe: int,
               block_q: int = 8, interpret: bool = False):
    """Fused IVF search (stages 1-3 above, no host round trip between them).

    -> (scores [nq, bq*nprobe*L], probe_blocks [nb, bq*nprobe]); row i's
    candidate j came from cluster probe_blocks[i // bq, j // L], slot j % L.
    """
    q, nb = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)  # same normalization as the jnp reference, by definition
    probe_blocks = ivf_probes(q, jnp.asarray(centroids), nprobe, block_q)
    scores = cluster_scan(q, store, mask, probe_blocks, block_q=block_q,
                          normalize=False, interpret=interpret)
    return scores[: len(queries)], probe_blocks


# ---------------------------------------------------------------------------
# Device-sharded cluster scan (shard_map over the cluster axis)
# ---------------------------------------------------------------------------


def sharded_ivf_search(queries, centroids, store, mask, *, nprobe: int,
                       n_shards: int, block_q: int = 8, mesh=None,
                       interpret: bool = False, use_pallas: bool = False):
    """Device-sharded IVF search: the inverted file's per-cluster tiles are
    partitioned across ``n_shards`` devices along the cluster axis; probe
    selection stays global (centroids are tiny and replicated), and every
    device scans only the probed clusters *it owns* — out-of-shard probe
    slots score MASKED_SCORE and the per-device score planes combine with
    one ``pmax`` across the mesh axis.  Each candidate is scored by exactly
    its home device, so the combined plane is identical to the unsharded
    :func:`ivf_search` while per-device work drops to the local probed
    clusters.  jnp contract: ``repro.kernels.ref.sharded_ivf_search_ref``.

    ``use_pallas`` runs :func:`cluster_scan` per shard (TPU); otherwise the
    shard body is the reference gather math (CPU multi-device meshes).
    -> (scores [nq, bq*nprobe*L], probe_blocks [nb, bq*nprobe]).
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.ref import ivf_scan_ref
    from repro.kernels.similarity import shard_mesh, shard_map

    q, nb = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)
    probe_blocks = ivf_probes(q, jnp.asarray(centroids), nprobe, block_q)
    kc, L, d = store.shape
    mesh = mesh if mesh is not None else shard_mesh(n_shards)
    local = max(1, -(-kc // n_shards))
    pad = n_shards * local - kc
    st = jnp.asarray(store)
    mk = jnp.asarray(mask)
    if pad:
        # equal tiles per device; padded clusters are never probed (probe
        # ids are < kc) and their mask is zero anyway
        st = jnp.concatenate([st, jnp.zeros((pad, L, d), st.dtype)])
        mk = jnp.concatenate([mk, jnp.zeros((pad, L), mk.dtype)])

    def body(q, p, st_local, mk_local):
        offset = jax.lax.axis_index("shard") * st_local.shape[0]
        local_p = p - offset
        in_range = (local_p >= 0) & (local_p < st_local.shape[0])
        safe = jnp.where(in_range, local_p, 0).astype(jnp.int32)
        if use_pallas:
            s = cluster_scan(q, st_local, mk_local, safe, block_q=block_q,
                             normalize=False, interpret=interpret)
        else:
            s = ivf_scan_ref(q, st_local, mk_local, safe, block_q=block_q,
                             normalize=False)
        keep = jnp.repeat(jnp.repeat(in_range, L, axis=1), block_q, axis=0)
        s = jnp.where(keep, s, MASKED_SCORE)
        return jax.lax.pmax(s, "shard")

    scores = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P("shard", None, None), P("shard", None)),
        out_specs=P(),
        check_rep=False)(q, probe_blocks, st, mk)
    return scores[: len(queries)], probe_blocks
