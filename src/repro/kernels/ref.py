"""Pure-jnp reference oracles for every Pallas kernel.

These define the numerics the kernels must match (tests sweep shapes/dtypes
and assert_allclose against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q:[B,Sq,H,hd], k/v:[B,Sk,Hk,hd] (GQA) -> [B,Sq,H,hd]; softmax in f32."""
    h, hk = q.shape[2], k.shape[2]
    if hk != h:
        k = jnp.repeat(k, h // hk, axis=2)
        v = jnp.repeat(v, h // hk, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    q_pos, k_pos = jnp.arange(sq), jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    if window:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


def decode_attention_ref(q, k, v, lens):
    """q:[B,1,H,hd], k/v:[B,S,Hk,hd], lens:[B] -> [B,1,H,hd].

    Attends to positions 0..lens[b] inclusive (the new token already written)."""
    h, hk = q.shape[2], k.shape[2]
    if hk != h:
        k = jnp.repeat(k, h // hk, axis=2)
        v = jnp.repeat(v, h // hk, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(k.shape[1])[None, :] <= lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


def similarity_ref(queries, corpus, *, normalize: bool = True):
    """queries:[nq,d], corpus:[nc,d] -> [nq,nc] cosine/inner-product scores."""
    q = jnp.asarray(queries, jnp.float32)
    c = jnp.asarray(corpus, jnp.float32)
    if normalize:
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
        c = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), 1e-9)
    return q @ c.T


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    """x:[..., d], scale:[d] -> same shape; stats in f32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
