"""Pure-jnp reference oracles for every Pallas kernel.

These define the numerics the kernels must match (tests sweep shapes/dtypes
and assert_allclose against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.index.backend import MASKED_SCORE  # canonical, numpy-only home

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q:[B,Sq,H,hd], k/v:[B,Sk,Hk,hd] (GQA) -> [B,Sq,H,hd]; softmax in f32."""
    h, hk = q.shape[2], k.shape[2]
    if hk != h:
        k = jnp.repeat(k, h // hk, axis=2)
        v = jnp.repeat(v, h // hk, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    q_pos, k_pos = jnp.arange(sq), jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    if window:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


def decode_attention_ref(q, k, v, lens):
    """q:[B,1,H,hd], k/v:[B,S,Hk,hd], lens:[B] -> [B,1,H,hd].

    Attends to positions 0..lens[b] inclusive (the new token already written)."""
    h, hk = q.shape[2], k.shape[2]
    if hk != h:
        k = jnp.repeat(k, h // hk, axis=2)
        v = jnp.repeat(v, h // hk, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(k.shape[1])[None, :] <= lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


def similarity_ref(queries, corpus, *, normalize: bool = True):
    """queries:[nq,d], corpus:[nc,d] -> [nq,nc] cosine/inner-product scores."""
    q = jnp.asarray(queries, jnp.float32)
    c = jnp.asarray(corpus, jnp.float32)
    if normalize:
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
        c = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), 1e-9)
    return q @ c.T


# -- IVF cluster scan (shared helpers + jnp reference) ----------------------


def _unitize(q):
    return q * jax.lax.rsqrt(jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))


def pad_queries(q, block_q: int):
    """Pad [nq, d] -> [nb*block_q, d] by edge replication (replicated rows
    probe the same clusters as the last real query, so padding never drags
    unrelated clusters into a block's scan).  -> (padded, nb)."""
    nq = q.shape[0]
    nb = max(1, -(-nq // block_q))
    pad = nb * block_q - nq
    if pad:
        q = jnp.concatenate([q, jnp.repeat(q[-1:], pad, axis=0)], axis=0)
    return q, nb


def ivf_probes(q, centroids, nprobe: int, block_q: int):
    """Per-query top-``nprobe`` clusters by centroid score, concatenated per
    query block -> [nb, block_q*nprobe] int32.  Shared verbatim by the Pallas
    path and the jnp reference so probe selection can never diverge."""
    cs = jnp.asarray(q, jnp.float32) @ jnp.asarray(centroids, jnp.float32).T
    _, probe = jax.lax.top_k(cs, nprobe)                    # [nb*bq, nprobe]
    return probe.astype(jnp.int32).reshape(-1, block_q * nprobe)


def ivf_scan_ref(queries, store, mask, probe_blocks, *, block_q: int = 8,
                 normalize: bool = True):
    """Reference masked gather-scan: queries [nb*bq, d], store [kc, L, d],
    mask [kc, L], probe_blocks [nb, slots] -> [nb*bq, slots*L]."""
    q = jnp.asarray(queries, jnp.float32)
    if normalize:
        q = _unitize(q)
    nb, slots = probe_blocks.shape
    L = store.shape[1]
    qb = q.reshape(nb, block_q, -1)
    v = jnp.asarray(store)[probe_blocks]                    # [nb, slots, L, d]
    s = jnp.einsum("bqd,bsld->bqsl", qb, v.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    m = jnp.asarray(mask)[probe_blocks]                     # [nb, slots, L]
    s = jnp.where(m[:, None] > 0, s, MASKED_SCORE)
    return s.reshape(nb * block_q, slots * L)


def ivf_search_ref(queries, centroids, store, mask, *, nprobe: int,
                   block_q: int = 8):
    """jnp reference for `repro.kernels.ivf_scan.ivf_search` (same pipeline:
    centroid scoring -> per-query probes -> masked cluster scan)."""
    q, _ = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)
    probe_blocks = ivf_probes(q, centroids, nprobe, block_q)
    scores = ivf_scan_ref(q, store, mask, probe_blocks, block_q=block_q,
                          normalize=False)
    return scores[: len(queries)], probe_blocks


def ivf_delta_search_ref(queries, centroids, store, mask, delta_vectors, *,
                         nprobe: int, block_q: int = 8):
    """Delta-aware IVF reference (`repro.kernels.ops.ivf_delta_search`): the
    probed main-store scan of :func:`ivf_search_ref` with an *exact* scan of
    the append-only delta side buffer concatenated along the candidate axis
    — the numerics contract for ``IVFIndex.search`` after ``add()``.
    ``delta_vectors`` are unit rows (the buffer's storage convention, same
    as the store tiles) -> (scores [nq, slots*L + nd], probe_blocks)."""
    s, probe_blocks = ivf_search_ref(queries, centroids, store, mask,
                                     nprobe=nprobe, block_q=block_q)
    q = _unitize(jnp.asarray(queries, jnp.float32))
    ds = q @ jnp.asarray(delta_vectors, jnp.float32).T
    return jnp.concatenate([s, ds], axis=1), probe_blocks


# -- quantized IVF scan (jnp contracts for kernels/ivf_scan_q) --------------


def ivf_scan_q_ref(queries, store_q, scales, mask, probe_blocks, *,
                   block_q: int = 8, normalize: bool = True):
    """Reference fused dequantize+score gather-scan: queries [nb*bq, d],
    store_q [kc, L, d] int8, scales [kc, L] f32, mask [kc, L],
    probe_blocks [nb, slots] -> [nb*bq, slots*L].

    Dequantization is fused as one per-lane multiply AFTER the matmul (the
    per-vector scale factors out of the dot product) — exactly what the
    Pallas kernel does on the MXU output, so the two can never diverge."""
    q = jnp.asarray(queries, jnp.float32)
    if normalize:
        q = _unitize(q)
    nb, slots = probe_blocks.shape
    L = store_q.shape[1]
    qb = q.reshape(nb, block_q, -1)
    v = jnp.asarray(store_q)[probe_blocks].astype(jnp.float32)  # [nb,slots,L,d]
    s = jnp.einsum("bqd,bsld->bqsl", qb, v,
                   preferred_element_type=jnp.float32)
    s = s * jnp.asarray(scales, jnp.float32)[probe_blocks][:, None]
    m = jnp.asarray(mask)[probe_blocks]                         # [nb, slots, L]
    s = jnp.where(m[:, None] > 0, s, MASKED_SCORE)
    return s.reshape(nb * block_q, slots * L)


def ivf_search_q_ref(queries, centroids, store_q, scales, mask, *,
                     nprobe: int, block_q: int = 8):
    """jnp reference for `repro.kernels.ivf_scan_q.ivf_search_q`: the exact
    :func:`ivf_search_ref` pipeline (shared probe selection included) with
    the quantized cluster scan in stage 3."""
    q, _ = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)
    probe_blocks = ivf_probes(q, centroids, nprobe, block_q)
    scores = ivf_scan_q_ref(q, store_q, scales, mask, probe_blocks,
                            block_q=block_q, normalize=False)
    return scores[: len(queries)], probe_blocks


def ivf_delta_search_q_ref(queries, centroids, store_q, scales, mask,
                           delta_q, delta_scales, *, nprobe: int,
                           block_q: int = 8):
    """Quantized delta-aware IVF reference: the probed quantized main-store
    scan plus an exact (dequantize-fused) scan of the int8 delta side buffer
    concatenated along the candidate axis — the numerics contract for
    ``IVFIndex(quantize="int8").search`` after ``add()``."""
    s, probe_blocks = ivf_search_q_ref(queries, centroids, store_q, scales,
                                       mask, nprobe=nprobe, block_q=block_q)
    q = _unitize(jnp.asarray(queries, jnp.float32))
    ds = (q @ jnp.asarray(delta_q).astype(jnp.float32).T) \
        * jnp.asarray(delta_scales, jnp.float32)[None, :]
    return jnp.concatenate([s, ds], axis=1), probe_blocks


def sharded_ivf_search_q_ref(queries, centroids, store_q, scales, mask, *,
                             nprobe: int, n_shards: int, block_q: int = 8):
    """jnp contract for ``ops.sharded_ivf_search_q``: identical sharding
    discipline to :func:`sharded_ivf_search_ref` (cluster tiles partitioned
    across devices, global probe selection, per-shard scans of locally-owned
    probed clusters combined by elementwise max) over the quantized store —
    the combined plane is identical to the unsharded
    :func:`ivf_search_q_ref`."""
    q, _ = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)
    probe_blocks = ivf_probes(q, centroids, nprobe, block_q)
    kc, L, _ = store_q.shape
    local = max(1, -(-kc // n_shards))
    nb, slots = probe_blocks.shape
    combined = jnp.full((nb * block_q, slots * L), MASKED_SCORE, jnp.float32)
    for s in range(n_shards):
        lo, hi = s * local, min((s + 1) * local, kc)
        in_range = (probe_blocks >= lo) & (probe_blocks < hi)   # [nb, slots]
        safe = jnp.where(in_range, probe_blocks, lo)
        sc = ivf_scan_q_ref(q, store_q[lo:hi], scales[lo:hi], mask[lo:hi],
                            safe - lo, block_q=block_q, normalize=False)
        keep = jnp.repeat(jnp.repeat(in_range, L, axis=1), block_q, axis=0)
        combined = jnp.maximum(combined,
                               jnp.where(keep, sc, MASKED_SCORE))
    return combined[: len(queries)], probe_blocks


# -- device-sharded retrieval (jnp contracts for the shard_map wrappers) ----


def pad_corpus_shards(corpus, n_shards: int):
    """Pad [nc, d] -> [n_shards*local, d] plus a validity mask [padded] so
    every shard holds an identically-shaped tile.  -> (padded, valid, local)."""
    nc = corpus.shape[0]
    local = max(1, -(-nc // n_shards))
    pad = n_shards * local - nc
    valid = jnp.concatenate([jnp.ones(nc, jnp.float32),
                             jnp.zeros(pad, jnp.float32)])
    if pad:
        corpus = jnp.concatenate(
            [corpus, jnp.zeros((pad, corpus.shape[1]), corpus.dtype)])
    return corpus, valid, local


def shard_topk_merge(scores, indices, k: int):
    """Host-side merge of per-shard top-k candidate lists: [nq, S*k] each ->
    (scores [nq, k], idx [nq, k]) descending, ties to the lowest index.

    Candidates arrive grouped by shard (ascending global index within and
    across groups is NOT guaranteed), so ties are broken by explicit index
    rather than stable position."""
    import numpy as np
    s = np.asarray(scores)
    i = np.asarray(indices)
    # lexsort: primary descending score, secondary ascending global index —
    # the same tie rule a full-corpus lax.top_k applies
    order = np.lexsort((i, -s), axis=1)
    k = min(k, s.shape[1])
    take = order[:, :k]
    return (np.take_along_axis(s, take, axis=1),
            np.take_along_axis(i, take, axis=1))


def sharded_search_ref(queries, corpus, k: int, n_shards: int, *,
                       normalize: bool = True):
    """jnp contract for ``repro.kernels.ops.sharded_search``: the corpus is
    row-partitioned into ``n_shards`` equal tiles, every shard scores its
    local tile (the similarity kernel's math) and keeps a local top-k, and
    the per-shard candidates are merged on host.  Lossless: each global
    winner is its home shard's local winner, so the merged top-k equals a
    full exact scan's.  -> (scores [nq, k], idx [nq, k])."""
    q = jnp.asarray(queries, jnp.float32)
    c = jnp.asarray(corpus, jnp.float32)
    if normalize:
        q = _unitize(q)
        c = _unitize(c)
    c, valid, local = pad_corpus_shards(c, n_shards)
    k_l = min(k, local)
    tiles = c.reshape(n_shards, local, -1)
    vmask = valid.reshape(n_shards, local)
    all_s, all_i = [], []
    for s in range(n_shards):
        sc = q @ tiles[s].T
        sc = jnp.where(vmask[s][None, :] > 0, sc, MASKED_SCORE)
        vals, loc = jax.lax.top_k(sc, k_l)
        all_s.append(vals)
        all_i.append(loc + s * local)
    return shard_topk_merge(jnp.concatenate(all_s, axis=1),
                            jnp.concatenate(all_i, axis=1), k)


def sharded_ivf_search_ref(queries, centroids, store, mask, *, nprobe: int,
                           n_shards: int, block_q: int = 8):
    """jnp contract for ``ops.sharded_ivf_search``: the padded per-cluster
    tiles are partitioned across ``n_shards`` devices along the cluster
    axis; every shard scans only the probed clusters it owns (the rest of
    its slots score MASKED_SCORE) and the per-shard score planes combine by
    elementwise max.  Each candidate is scored by exactly its home shard,
    so the combined plane is *identical* to the unsharded
    :func:`ivf_search_ref` — sharding redistributes work, never results."""
    q, _ = pad_queries(jnp.asarray(queries, jnp.float32), block_q)
    q = _unitize(q)
    probe_blocks = ivf_probes(q, centroids, nprobe, block_q)
    kc, L, _ = store.shape
    local = max(1, -(-kc // n_shards))
    nb, slots = probe_blocks.shape
    combined = jnp.full((nb * block_q, slots * L), MASKED_SCORE, jnp.float32)
    for s in range(n_shards):
        lo, hi = s * local, min((s + 1) * local, kc)
        in_range = (probe_blocks >= lo) & (probe_blocks < hi)   # [nb, slots]
        safe = jnp.where(in_range, probe_blocks, lo)
        sc = ivf_scan_ref(q, store[lo:hi], mask[lo:hi], safe - lo,
                          block_q=block_q, normalize=False)
        keep = jnp.repeat(jnp.repeat(in_range, L, axis=1), block_q, axis=0)
        combined = jnp.maximum(combined,
                               jnp.where(keep, sc, MASKED_SCORE))
    return combined[: len(queries)], probe_blocks


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    """x:[..., d], scale:[d] -> same shape; stats in f32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
