"""Serving gateway CLI: run many concurrent semantic pipelines as tenant
sessions through one shared runtime — cross-query micro-batching, a shared
semantic cache (optionally persisted across runs), fair multi-tenant
scheduling, and gateway metrics.

    # simulated backend (no weights needed): 8 sessions, 2 tenants
    PYTHONPATH=src python -m repro.launch.serve --sessions 8 --tenants 2

    # real JAX engines under the dispatcher (smoke-scale random weights)
    PYTHONPATH=src python -m repro.launch.serve --backend engine --sessions 4

    # persist the semantic cache: the second run answers from disk
    PYTHONPATH=src python -m repro.launch.serve --persist /tmp/semcache.jsonl
"""
from __future__ import annotations

import argparse
import json
import time


def _sim_session(n_records: int, seed: int):
    from repro.core.backends import synth
    from repro.core.frame import SemFrame, Session

    left, right, world, *_ = synth.make_join_world(n_records, 10, seed=seed)
    synth.add_phrase_predicate(world, left, "is checkable", 0.3, seed=seed)
    synth.add_phrase_predicate(world, left, "is in English", 0.85, seed=seed)
    # proxy quality / sample size chosen so guaranteed cascades calibrate
    # real auto-accept/reject regions (--audit then has decisions to sample)
    sess = Session(oracle=synth.SimulatedModel(world, "oracle"),
                   proxy=synth.SimulatedModel(world, "proxy", alpha=2.5),
                   embedder=synth.SimulatedEmbedder(world), sample_size=100,
                   seed=seed)
    return sess, left, right, SemFrame


def _engine_session(n_records: int, max_seq: int):
    from repro.core.backends.jax_engine import make_session
    from repro.core.frame import SemFrame

    sess = make_session(max_seq=max_seq)
    left = [{"id": f"rec{i}",
             "doc": f"record {i}: component-{i % 5} paired with module-{i % 3}"}
            for i in range(n_records)]
    right = [{"id": f"mod{j}", "module": f"module-{j}"} for j in range(3)]
    return sess, left, right, SemFrame


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("sim", "engine"), default="sim")
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--records", type=int, default=40)
    ap.add_argument("--max-inflight", type=int, default=4)
    ap.add_argument("--max-pending", type=int, default=64)
    ap.add_argument("--window-ms", type=float, default=5.0)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--cache-ttl", type=float, default=None,
                    help="shared-cache TTL in seconds (default: no expiry)")
    ap.add_argument("--cache-capacity", type=int, default=100_000)
    ap.add_argument("--persist", type=str, default=None,
                    help="JSONL path for the persistent semantic cache")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-session deadline in seconds")
    ap.add_argument("--no-optimize", action="store_true")
    ap.add_argument("--audit", action="store_true",
                    help="enable online guarantee auditing (background gold "
                         "re-judgments of sampled cascade decisions)")
    ap.add_argument("--metrics-dump", type=str, default=None, metavar="PATH",
                    help="write the Prometheus text exposition of all "
                         "gateway/audit metrics to PATH before shutdown")
    ap.add_argument("--max-seq", type=int, default=256, help="engine backend")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.serve import AdmissionError, Gateway

    t0 = time.time()
    if args.backend == "sim":
        sess, left, right, SemFrame = _sim_session(args.records, args.seed)
    else:
        sess, left, right, SemFrame = _engine_session(args.records, args.max_seq)
    print(f"[serve] {args.backend} backend ready in {time.time()-t0:.1f}s")

    gw = Gateway(sess, max_inflight=args.max_inflight,
                 max_pending=args.max_pending,
                 window_s=args.window_ms / 1e3, max_batch=args.max_batch,
                 cache_ttl_s=args.cache_ttl,
                 cache_capacity=args.cache_capacity,
                 persist_path=args.persist,
                 audit=True if args.audit else None)

    def submit_with_backpressure(pipeline, **kw):
        while True:
            try:
                return gw.submit(pipeline, **kw)
            except AdmissionError:   # queue full: wait for capacity, retry
                time.sleep(0.01)

    def pipeline(i: int):
        sf = SemFrame(left, gw.session).lazy()
        if args.backend == "sim":
            # half the tenants share the checkable predicate — the
            # cross-query sharing regime; with --audit the filters run as
            # guaranteed cascades so the auditor has decisions to sample
            targets = ({"recall_target": 0.9, "precision_target": 0.9}
                       if args.audit else {})
            sf = sf.sem_filter("the {abstract} is checkable" if i % 2 == 0
                               else "the {abstract} is in English", **targets)
            return sf.sem_join(right,
                               "the {abstract} reports the {reaction:right}")
        return (sf.sem_map("one-line gist of {doc}", out_column="gist")
                  .sem_filter("the {doc} mentions a component"))

    try:
        t0 = time.time()
        handles = [submit_with_backpressure(
                       pipeline(i), tenant=f"tenant{i % args.tenants}",
                       optimize=not args.no_optimize,
                       deadline_s=args.deadline)
                   for i in range(args.sessions)]
        gw.wait_all()
        dt = time.time() - t0

        for h in handles:
            print("[serve]", json.dumps(h.summary()))
        snap = gw.snapshot()
        print(f"[serve] {snap['completed']}/{args.sessions} sessions in {dt:.2f}s "
              f"({snap['throughput_rps']:.2f}/s, p50 {snap['p50_latency_s']}s, "
              f"p95 {snap['p95_latency_s']}s)")
        print(f"[serve] cross-query hit rate {snap['cross_query_hit_rate']:.2f}, "
              f"dispatcher fused {snap['dispatch']['fused_calls']} calls into "
              f"{snap['dispatch']['fused_batches']} batches "
              f"({snap['dispatch']['backend_prompts']} backend prompts for "
              f"{snap['dispatch']['requested_prompts']} requested)")
        print("[serve]", json.dumps({k: v for k, v in snap.items()
                                     if k in ("cache", "dispatch")}))
        if gw.auditor is not None:
            gw.auditor.drain()
            print("[serve] audit", json.dumps(gw.auditor.report()))
        if args.metrics_dump:
            with open(args.metrics_dump, "w", encoding="utf-8") as fh:
                fh.write(gw.metrics_text())
            print(f"[serve] metrics exposition written to {args.metrics_dump}")
    finally:
        gw.close()


if __name__ == "__main__":
    main()
