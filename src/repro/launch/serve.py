"""Serving driver: bring up oracle/proxy engines + embedder and execute a
semantic-operator program against them — the production entry point of the
paper's system (LOTUS front-end, inference-engine back-end).

    PYTHONPATH=src python -m repro.launch.serve --requests 24
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.backends.jax_engine import make_session
from repro.core.frame import SemFrame


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--recall-target", type=float, default=0.8)
    ap.add_argument("--precision-target", type=float, default=0.8)
    ap.add_argument("--delta", type=float, default=0.3)
    args = ap.parse_args()

    t0 = time.time()
    sess = make_session(max_seq=args.max_seq)
    print(f"[serve] engines ready in {time.time()-t0:.1f}s")

    records = [{"doc": f"record {i}: component-{i % 5} paired with module-{i % 3}"}
               for i in range(args.requests)]
    sf = SemFrame(records, sess)

    t0 = time.time()
    out = (sf.sem_map("one-line gist of {doc}", out_column="gist")
             .sem_filter("the {doc} mentions a component",
                         recall_target=args.recall_target,
                         precision_target=args.precision_target,
                         delta=args.delta))
    dt = time.time() - t0
    stats = [s for s in sf.stats_log]
    print(f"[serve] pipeline over {args.requests} records in {dt:.1f}s")
    for s in stats:
        print("[serve]", json.dumps(s))
    eng = sess.oracle._m.engine
    print(f"[serve] oracle engine: {eng.stats.lm_calls} calls, "
          f"{eng.stats.generated_tokens} generated tokens")


if __name__ == "__main__":
    main()
