"""Dry-run profiler: top memory/collective/flop contributors of a cell's HLO
with loop-trip multipliers — the 'profile' of the §Perf hypothesis loop.

    PYTHONPATH=src python -m repro.launch.hlo_debug --arch zamba2-7b --shape train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
import argparse

from repro.launch.hlo_analysis import Analyzer, parse, scope_of


def top_contributors(hlo_text: str, n: int = 20):
    m = parse(hlo_text)
    a = Analyzer(m)
    rows = []

    def walk(cname, mult=1.0):
        for ins in m.computations.get(cname, []):
            op = ins.op
            if op == "while":
                body, cond = ins.attr("body"), ins.attr("condition")
                trips = a._trip_count(cond) if cond else 1
                if body:
                    walk(body, mult * trips)
            elif op == "call":
                sub = ins.attr("to") or ins.attr("calls")
                if sub:
                    walk(sub, mult)
            elif op in ("parameter", "constant", "tuple", "get-tuple-element",
                        "bitcast", "copy", "iota"):
                continue
            else:
                b = a._io_bytes(ins)
                f = 0.0
                if op in ("dot", "convolution"):
                    f = a._dot_flops(ins)
                elif op == "fusion":
                    called = ins.attr("calls")
                    if called:
                        f = a.computation(called).flops
                rows.append((b * mult, f * mult, op, ins.name, ins.type_str[:70],
                             mult, scope_of(ins.rest) or ""))

    walk(m.entry)
    rows.sort(reverse=True)
    return rows[:n], rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered, meta = build_cell(args.arch, args.shape, mesh, rules=args.rules)
    compiled = lowered.compile()
    text = compiled.as_text()
    top, rows = top_contributors(text, args.top)
    total_b = sum(r[0] for r in rows)
    total_f = sum(r[1] for r in rows)
    print(f"total bytes/dev {total_b/1e9:.1f}GB  flops/dev {total_f/1e12:.2f}T")
    print(f"{'GB':>9} {'GF':>9} {'x':>6} {'op':20} {'scope':10} name/type")
    for b, f, op, name, ty, mult, sc in top:
        print(f"{b/1e9:9.2f} {f/1e9:9.1f} {mult:6.0f} {op:20} {sc:10} {name[:28]:28} {ty}")
    ma = compiled.memory_analysis()
    print("memory:", {k: round(getattr(ma, k + '_size_in_bytes', 0)/1e9, 2)
                      for k in ("argument", "output", "temp", "alias")})


if __name__ == "__main__":
    main()
