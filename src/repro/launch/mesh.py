"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax>=0.5 wants explicit Auto axis types; 0.4.x has no axis_types kwarg.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod topology: 16x16 = 256 chips per pod; 2 pods = 512 via DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires enough host devices)."""
    return _mesh(shape, axes)
