"""Render the roofline table from dry-run artifacts into EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def build_table(art_dir: str) -> str:
    rows = []
    skips = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("status") == "skipped":
            skips.append((d["mesh"], d["arch"], d["shape"]))
            continue
        if d.get("status") != "ok":
            rows.append((d["mesh"], d["arch"], d["shape"], d.get("status"), {}))
            continue
        rows.append((d["mesh"], d["arch"], d["shape"], "ok", d))
    rows.sort(key=lambda r: (r[0], r[1], SHAPE_ORDER.get(r[2], 9)))

    out = ["| mesh | arch | shape | bottleneck | t_comp | t_mem | t_mem_flash | t_coll | step_s | MFU | MFU_flash | useful | peak GB | ideal GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for mesh, arch, shape, status, d in rows:
        if status != "ok":
            out.append(f"| {mesh} | {arch} | {shape} | {status.upper()} | | | | | | | | | | |")
            continue
        r = d["roofline"]
        peak = r["mem_per_dev"].get("peak", 0) / 1e9
        ideal = d.get("ideal_bytes_per_dev", 0) / 1e9
        out.append(
            f"| {mesh} | {arch} | {shape} | {r['bottleneck']} | "
            f"{r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} | "
            f"{r['t_memory_flash_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['step_time_s']:.3g} | {r['mfu']:.3f} | {r['mfu_flash']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {peak:.1f} | {ideal:.2f} |")
    out.append("")
    out.append(f"Skipped cells ({len(skips)}): long_500k for pure full-attention "
               "archs per the assignment — "
               + ", ".join(sorted({a for _, a, _ in skips})) + ".")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--write", action="store_true", help="inject into EXPERIMENTS.md")
    args = ap.parse_args()
    table = build_table(args.dir)
    print(table)
    if args.write:
        path = "EXPERIMENTS.md"
        text = open(path).read()
        marker = "<!-- ROOFLINE_TABLE -->"
        if marker in text:
            text = text.replace(marker, marker + "\n\n" + table)
            open(path, "w").write(text)
            print(f"\n[report] table injected into {path}")


if __name__ == "__main__":
    main()
