import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any jax-importing module — jax
# locks the device count at first init.  REPRO_DRYRUN_DEVICES overrides for
# small local debugging runs.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step including the
optimizer update, or serve prefill/decode against a full-size KV cache),
lowers it with ShapeDtypeStruct stand-ins (no allocation — a 400B-param tree
never materializes), compiles for the production mesh, and records
memory_analysis / cost_analysis / the collective schedule into a JSON
artifact consumed by the roofline report (EXPERIMENTS.md §Dry-run/§Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import common
from repro.configs import ARCHS, SHAPES, cell_applicable, get_config, input_specs
from repro.dist import sharding as shd
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.trainstep import make_train_step

# Per-arch dry-run knobs (memory-driven; see EXPERIMENTS.md §Dry-run notes).
# Default is NO gradient accumulation: with FSDP residency the weights are
# re-gathered once per microbatch, so fewer microbatches = less collective
# traffic; memory is held down by remat + model-sharded saved residuals
# (embed_act rule) instead.
TRAIN_MICROBATCHES: dict[str, int] = {}
DEFAULT_MICROBATCHES = 1
# 400B + f32 Adam does not fit 256x16GB; single-pod uses bf16 moments, no
# master (stochastic-rounding-free bf16 update; documented deviation).
OPT_OVERRIDES = {
    "llama4-maverick-400b-a17b": dict(state_dtype="bfloat16", use_master=False),
}
SERVE_RULES = {  # weights-replicated-over-data serving for <=72B; FSDP for 400B
    "llama4-maverick-400b-a17b": "default",
}


def _input_shardings(specs: dict, mesh, rules_name: str) -> dict:
    rules = shd.RULE_TABLES[rules_name]
    out = {}
    for name, s in specs.items():
        if name in ("tokens", "labels"):
            axes = ("batch", "seq")
        elif name in ("image_embeds", "audio_frames"):
            axes = ("batch", "frames", "embed_act")
        else:  # cache_len scalar
            axes = ()
        out[name] = NamedSharding(mesh, shd.resolve_pspec(s.shape, axes, mesh, rules))
    return out


def build_cell(arch: str, shape: str, mesh, *, rules: str | None = None,
               microbatches: int | None = None):
    """Returns (lowered, meta) for one (arch x shape) on ``mesh``."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return None, {"skipped": why}

    pspecs = registry.param_specs(cfg)
    params = common.param_structs(pspecs)
    t0 = time.time()

    ospecs = cspecs = None
    if cell.kind == "train":
        rules = rules or "default"
        opt_cfg = opt.OptimizerConfig(**OPT_OVERRIDES.get(arch, {}))
        ospecs = opt.state_specs(pspecs, opt_cfg)
        opt_structs = common.param_structs(ospecs)
        mb = microbatches or TRAIN_MICROBATCHES.get(arch, DEFAULT_MICROBATCHES)
        step = make_train_step(cfg, opt_cfg, microbatches=mb)
        in_specs = input_specs(cfg, cell)
        batch = dict(in_specs)
        shardings = (
            shd.spec_shardings(pspecs, mesh, rules),
            shd.spec_shardings(ospecs, mesh, rules),
            _input_shardings(in_specs, mesh, rules),
        )
        with shd.set_mesh(mesh), shd.activation_rules(mesh, rules):
            jitted = jax.jit(step, in_shardings=shardings,
                             out_shardings=(shardings[0], shardings[1], None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt_structs, batch)
        meta = {"kind": "train", "microbatches": mb, "rules": rules}

    elif cell.kind == "prefill":
        rules = rules or SERVE_RULES.get(arch, "serve_replicated")
        cspecs = registry.cache_specs(cfg, cell.global_batch, cell.seq_len)
        cache = common.param_structs(cspecs)
        in_specs = input_specs(cfg, cell)
        extra_keys = [k for k in in_specs if k not in ("tokens",)]

        def serve_prefill(params, tokens, cache, extra):
            logits, cache = registry.prefill(cfg, params, tokens, cache,
                                             extra=extra or None, last_only=True)
            return logits[:, 0].astype(jnp.float32), cache

        ish = _input_shardings(in_specs, mesh, rules)
        extra = {k: in_specs[k] for k in extra_keys} or None
        extra_sh = {k: ish[k] for k in extra_keys} or None
        shardings = (shd.spec_shardings(pspecs, mesh, rules), ish["tokens"],
                     shd.spec_shardings(cspecs, mesh, rules), extra_sh)
        with shd.set_mesh(mesh), shd.activation_rules(mesh, rules):
            jitted = jax.jit(serve_prefill, in_shardings=shardings,
                             out_shardings=(None, shardings[2]), donate_argnums=(2,))
            lowered = jitted.lower(params, in_specs["tokens"], cache, extra)
        meta = {"kind": "prefill", "rules": rules}

    else:  # decode
        rules = rules or SERVE_RULES.get(arch, "serve_replicated")
        cfg = cfg.with_(decode_cp=True)  # shard_map context-parallel decode
        cspecs = registry.cache_specs(cfg, cell.global_batch, cell.seq_len)
        cache = common.param_structs(cspecs)
        in_specs = input_specs(cfg, cell)

        def serve_step(params, tokens, cache, cache_len):
            logits, cache = registry.decode_step(cfg, params, tokens, cache, cache_len)
            return logits[:, 0].astype(jnp.float32), cache

        ish = _input_shardings(in_specs, mesh, rules)
        shardings = (shd.spec_shardings(pspecs, mesh, rules), ish["tokens"],
                     shd.spec_shardings(cspecs, mesh, rules), ish["cache_len"])
        with shd.set_mesh(mesh), shd.activation_rules(mesh, rules):
            jitted = jax.jit(serve_step, in_shardings=shardings,
                             out_shardings=(None, shardings[2]), donate_argnums=(2,))
            lowered = jitted.lower(params, in_specs["tokens"], cache,
                                   in_specs["cache_len"])
        meta = {"kind": "decode", "rules": rules}

    meta["lower_s"] = time.time() - t0
    meta["param_count"] = common.param_count(pspecs)
    meta["active_param_count"] = cfg.active_param_count()
    # analytic lower bound on per-device HBM traffic for one step (the
    # roofline floor: weights/caches/optimizer state each touched once-ish;
    # see EXPERIMENTS.md §Roofline notes)
    chips = mesh.devices.size
    pbytes = common.param_bytes(pspecs)
    if cell.kind == "train":
        obytes = common.param_bytes(ospecs)
        act = cell.global_batch * cell.seq_len * cfg.d_model * 2 * max(cfg.num_layers, 1)
        ideal = 3 * pbytes + 2 * obytes + act  # fwd+remat+bwd reads, opt rw, residuals
    else:
        cbytes = common.param_bytes(cspecs) if cell.kind != "train" else 0
        ideal = pbytes + cbytes
    meta["ideal_bytes_per_dev"] = ideal / chips
    return lowered, meta


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str, *,
             rules: str | None = None, microbatches: int | None = None,
             save_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    cfg = get_config(arch)
    cell = SHAPES[shape]
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips}
    try:
        lowered, meta = build_cell(arch, shape, mesh, rules=rules, microbatches=microbatches)
        rec.update(meta)
        if lowered is None:
            rec["status"] = "skipped"
        else:
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t0
            hlo = compiled.as_text()
            rl = roofline.analyse(compiled, hlo, arch=arch, shape=shape,
                                  mesh_name=mesh_name, chips=chips,
                                  model_flops=roofline.model_flops_for_cell(cfg, cell),
                                  seq_len=cell.seq_len)
            rec["roofline"] = rl.to_json()
            rec["status"] = "ok"
            if save_hlo:
                with open(os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.hlo"), "w") as f:
                    f.write(hlo)
    except Exception as e:  # noqa: BLE001 - recorded as a failing cell
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all and not args.arch:
        ap.error("pass --arch/--shape or --all")

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_done and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_name, args.out, rules=args.rules,
                               microbatches=args.microbatches, save_hlo=args.save_hlo)
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"bottleneck={r['bottleneck']} step={r['step_time_s']:.4g}s "
                             f"mfu={r['mfu']:.3f}")
                elif status == "error":
                    extra = rec["error"][:120]
                print(f"[dryrun] {mesh_name:6s} {arch:26s} {shape:12s} {status:8s} "
                      f"({time.time()-t0:.1f}s) {extra}", flush=True)
    print(f"[dryrun] done ok={n_ok} skipped={n_skip} errors={n_err}", flush=True)


if __name__ == "__main__":
    main()
