"""Cluster training driver.

Single-controller on this host; on a real multi-host TPU cluster pass
--coordinator/--num-processes/--process-id (jax.distributed) and each host
runs the same binary — the GSPMD program, checkpoint layout, and data shards
are already multi-host-aware (shard_id = process index).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --steps 100 \
        [--mesh-data 16 --mesh-model 16 --rules default] \
        [--coordinator host:1234 --num-processes 64 --process-id 0]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke
from repro.data.tokenizer import TOKENIZER
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    # multi-host
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_processes,
                                   process_id=args.process_id)

    cfg = (get_smoke(args.arch) if args.smoke else get_config(args.arch))
    cfg = cfg.with_(vocab_size=TOKENIZER.vocab_size) if args.smoke else cfg
    loop = LoopConfig(steps=args.steps, batch=args.batch, seq_len=args.seq_len,
                      microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      compress_grads=args.compress_grads,
                      shard_id=args.process_id, num_shards=args.num_processes)
    ocfg = opt.OptimizerConfig(learning_rate=args.lr, total_steps=args.steps,
                               warmup_steps=max(args.steps // 20, 1))
    metrics = run(cfg, ocfg, loop)
    print("[train] final:", metrics)


if __name__ == "__main__":
    main()
