"""Roofline-term derivation from compiled dry-run artifacts.

Terms (per step, across the whole mesh):
    compute    = HLO_FLOPs_global     / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_global     / (chips * HBM_BW)
    collective = collective_bytes_dev / ICI_BW          (per-device wire bytes)

``cost_analysis`` on the SPMD-compiled module reports *per-device* flops /
bytes (verified empirically in tests/test_roofline.py); we multiply by chip
count for the global terms.  Collective bytes are not in cost_analysis: we
parse the optimized HLO text, resolve each collective's operand shapes, and
sum operand bytes per device (ring transfer cost ~= operand bytes x (n-1)/n
for all-gather/reduce-scatter; all-reduce counted twice — see
``_COLLECTIVE_WIRE_FACTOR``).

Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any


PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (per-device injection, ~one link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# approximate wire bytes per device = factor * operand bytes
_COLLECTIVE_WIRE_FACTOR = {
    "all-gather": 1.0,        # operand is the local shard; ship it around the ring
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,        # RS + AG
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: 0.4.x returns a
    per-module list of dicts, newer versions one dict."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # pragma: no cover - backend without cost analysis
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def shape_bytes(type_str: str) -> int:
    """'bf16[8,128,4096]{...}' -> bytes. Tuples '(f32[..], f32[..])' summed."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device wire bytes by collective kind, parsed from optimized HLO."""
    # first pass: map instruction name -> result type string
    types: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            types[m.group(1).lstrip("%")] = m.group(2)

    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        body = line[m.end(2):] if False else line
        for kind in _COLLECTIVES:
            # match e.g. " = bf16[...] all-gather(%operand, ...)"
            km = re.search(rf"\s{re.escape(kind)}(?:-start|-done)?\(([^)]*)\)", body)
            if km is None:
                continue
            if f"{kind}-done" in body:   # -done carries no new wire traffic
                continue
            ops = [o.strip().lstrip("%") for o in km.group(1).split(",")]
            b = 0
            for op in ops:
                op = op.split(" ")[0]
                if op in types:
                    b += shape_bytes(types[op])
                else:  # inline-typed operand e.g. "bf16[8,16]{1,0} %fusion.3"
                    b += shape_bytes(op)
            out[kind] += b * _COLLECTIVE_WIRE_FACTOR[kind]
            break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float            # 6*N*D (active) for the step's tokens
    mem_per_dev: dict[str, float]
    coll_breakdown: dict[str, float]
    scopes: dict[str, list] = dataclasses.field(default_factory=dict)
    seq_len: int = 0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — remat/padding/capacity waste."""
        total = self.hlo_flops_per_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline step time."""
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time) \
            if self.step_time else 0.0

    # -- Pallas-flash adjusted memory term --------------------------------
    # The XLA (non-kernel) attention path materializes S^2 f32 score chains
    # in HBM; the Pallas flash kernel (repro.kernels.flash_attention) keeps
    # them in VMEM.  Adjusted traffic replaces the attn_core scope bytes with
    # the analytic flash traffic  F * (2/Bq + 2/S)  (KV re-reads per q-block
    # of Bq=1024 + q/o streams); see DESIGN.md and EXPERIMENTS.md §Roofline.
    @property
    def flash_adjusted_bytes(self) -> float:
        if "attn_core" not in self.scopes:
            return self.hlo_bytes_per_dev
        f_attn, b_attn = self.scopes["attn_core"]
        flash = f_attn * (2.0 / 1024.0 + (2.0 / self.seq_len if self.seq_len else 0.0))
        return self.hlo_bytes_per_dev - b_attn + flash

    @property
    def t_memory_flash(self) -> float:
        return self.flash_adjusted_bytes / HBM_BW

    @property
    def step_time_flash(self) -> float:
        return max(self.t_compute, self.t_memory_flash, self.t_collective)

    @property
    def mfu_flash(self) -> float:
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time_flash) \
            if self.step_time_flash else 0.0

    def to_json(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "hlo_bytes_per_dev": self.hlo_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck, "step_time_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio, "mfu": self.mfu,
            "t_memory_flash_s": self.t_memory_flash,
            "step_time_flash_s": self.step_time_flash, "mfu_flash": self.mfu_flash,
            "mem_per_dev": self.mem_per_dev,
            "coll_breakdown": self.coll_breakdown,
            "scopes": self.scopes,
        }


def model_flops_for_cell(cfg, cell) -> float:
    """6*N_active*D for train, 2*N_active*D for inference fwd (per step)."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def analyse(compiled, lowered_text: str, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float, seq_len: int = 0) -> Roofline:
    # Static HLO walk: XLA's cost_analysis does not multiply while-loop trip
    # counts (scan-over-layers would be undercounted ~100x) — see
    # hlo_analysis.py and tests/test_roofline.py.
    from repro.launch.hlo_analysis import analyze_text
    costs = analyze_text(lowered_text)
    flops = costs.flops
    byts = costs.bytes
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp": float(getattr(ma, "temp_size_in_bytes", 0)),
            "alias": float(getattr(ma, "alias_size_in_bytes", 0)),
        }
        mem["peak"] = mem["argument"] + mem["output"] + mem["temp"] - mem["alias"]
    except Exception:  # pragma: no cover
        mem = {}
    coll = dict(costs.coll)
    coll.setdefault("total", 0.0)
    mem["cpu_upcast_bytes_excluded"] = costs.cpu_upcast_bytes
    # cross-check fields (known-undercounting XLA numbers, kept for reference)
    ca = xla_cost_analysis(compiled)
    mem["xla_flops_nocount_loops"] = float(ca.get("flops", 0.0))
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops_per_dev=flops, hlo_bytes_per_dev=byts,
                    coll_bytes_per_dev=coll["total"], model_flops=model_flops,
                    mem_per_dev=mem, coll_breakdown=coll, scopes=dict(costs.scopes),
                    seq_len=seq_len)
