"""Static analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` does NOT multiply while-loop bodies by
their trip counts (verified in tests/test_roofline.py), which makes it
useless for scan-over-layers programs.  This module walks the HLO call graph
itself:

  * FLOPs: every ``dot``/``convolution``, 2 * prod(result) * contraction,
    recursing into fusions/calls/while bodies, multiplying while bodies by
    their trip count (parsed from the loop-condition's compare constant).
  * HBM bytes: per *top-level* (post-fusion) instruction, operands + result —
    i.e. the standard fused-HLO memory-traffic model.  In-place ops
    (dynamic-update-slice, scatter) count only the updated slice.
  * Collective bytes: operand bytes per collective (x2 for all-reduce),
    multiplied by enclosing trip counts.

Shapes in the per-device SPMD module are local, so all numbers are
per-device.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "bf16": 2, "f16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(  # tuple types may contain /*index=N*/ comments
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\]{},]+))\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_FACTOR = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str          # everything after the opening paren of the op

    @property
    def operands(self) -> list[str]:
        depth, i, end = 1, 0, len(self.rest)
        while i < len(self.rest):
            c = self.rest[i]
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
            i += 1
        return _OPERAND_RE.findall(self.rest[:end])

    def attr(self, key: str) -> str | None:
        m = re.search(rf"{key}=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str) -> list[int]:
        m = re.search(rf"{key}={{([\d,]*)}}", self.rest)
        return [int(x) for x in m.group(1).split(",") if x] if m else []


@dataclasses.dataclass
class Module:
    computations: dict[str, list[Instr]]
    entry: str
    types: dict[str, str]


def parse(text: str) -> Module:
    computations: dict[str, list[Instr]] = {}
    types: dict[str, str] = {}
    entry = ""
    cur: list[Instr] | None = None
    for line in text.splitlines():
        cm = _COMP_RE.match(line)
        if cm and ("->" in line):
            name = cm.group(1)
            cur = computations.setdefault(name, [])
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im and cur is not None:
            ins = Instr(im.group(1), im.group(2), im.group(3), im.group(4))
            cur.append(ins)
            types[ins.name] = ins.type_str
    return Module(computations, entry, types)


_SCOPE_TAGS = ("attn_core", "moe_ffn", "ssd_core")
_SCOPE_RE = re.compile(r'op_name="([^"]*)"')


def scope_of(rest: str) -> str | None:
    m = _SCOPE_RE.search(rest)
    if not m:
        return None
    for tag in _SCOPE_TAGS:
        if tag in m.group(1):
            return tag
    return None


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    cpu_upcast_bytes: float = 0.0   # XLA:CPU bf16->f32 dot-operand upcasts
    coll: dict[str, float] = dataclasses.field(default_factory=dict)
    # per named-scope (flops, bytes) attribution
    scopes: dict[str, list] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.cpu_upcast_bytes += other.cpu_upcast_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, (f, b) in other.scopes.items():
            cur = self.scopes.setdefault(k, [0.0, 0.0])
            cur[0] += f * mult
            cur[1] += b * mult

    def tag(self, rest: str, flops: float, byts: float) -> None:
        sc = scope_of(rest)
        if sc:
            cur = self.scopes.setdefault(sc, [0.0, 0.0])
            cur[0] += flops
            cur[1] += byts


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "bitcast-convert", "copy", "copy-start", "copy-done",
               "after-all", "partition-id", "replica-id", "iota"}


class Analyzer:
    def __init__(self, module: Module):
        self.m = module
        self._memo: dict[str, Costs] = {}

    # -- helpers ---------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        """Max s32 constant in the loop condition ~= trip count for scans."""
        best = 1
        for ins in self.m.computations.get(cond_name, []):
            if ins.op == "constant":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, ins: Instr) -> float:
        ops = ins.operands
        if not ops:
            return 0.0
        lhs_t = self.m.types.get(ops[0], "")
        dims = shape_dims(lhs_t)
        if not dims:
            return 0.0
        lhs_dims = dims[0][1]
        contract = 1
        for i in ins.attr_list("lhs_contracting_dims"):
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
        result = 1
        for _, ds in shape_dims(ins.type_str):
            for d in ds:
                result *= d
            break
        return 2.0 * result * contract

    def _root_op(self, comp_name: str) -> str:
        comp = self.m.computations.get(comp_name, [])
        return comp[-1].op if comp else ""

    def _io_bytes(self, ins: Instr) -> float:
        if ins.op in ("dynamic-update-slice",):
            ops = ins.operands
            upd = shape_bytes(self.m.types.get(ops[1], "")) if len(ops) > 1 else 0
            return 2.0 * upd  # read+write of the slice
        if ins.op == "scatter":
            ops = ins.operands
            upd = sum(shape_bytes(self.m.types.get(o, "")) for o in ops[2:])
            return 2.0 * upd
        if ins.op == "fusion":
            return self._fusion_io(ins)
        total = shape_bytes(ins.type_str)
        for o in ins.operands:
            total += shape_bytes(self.m.types.get(o, ""))
        return float(total)

    def _fusion_io(self, ins: Instr) -> float:
        """Traffic of a fusion = bytes actually *touched*, not operand sizes:

        * a parameter consumed only by dynamic-slice ops contributes the
          slice bytes (scan-over-layers KV caches would otherwise count the
          whole [L, ...] stacked buffer once per layer),
        * an in-place dynamic-update-slice of a buffer counts the update
          region for both the read and the write sides."""
        called = ins.attr("calls")
        comp = self.m.computations.get(called or "", [])
        if not comp:
            return float(shape_bytes(ins.type_str)
                         + sum(shape_bytes(self.m.types.get(o, "")) for o in ins.operands))
        by_name = {i.name: i for i in comp}
        consumers: dict[str, list[Instr]] = {}
        for i in comp:
            for o in i.operands:
                consumers.setdefault(o, []).append(i)

        # dtype converts / layout bitcasts are free on TPU (they fuse into the
        # surrounding op's pipeline); trace dataflow through them.
        TRANSPARENT = ("convert", "bitcast", "copy", "reshape")

        def terminals(name: str, depth: int = 0) -> list[Instr]:
            outs: list[Instr] = []
            for c in consumers.get(name, []):
                if c.op in TRANSPARENT and depth < 8:
                    outs.extend(terminals(c.name, depth + 1))
                else:
                    outs.append(c)
            return outs

        def upd_bytes(d: Instr) -> float:
            if len(d.operands) > 1:
                o = d.operands[1]
                b = shape_bytes(self.m.types.get(o, ""))
                if not b and o in by_name:
                    b = shape_bytes(by_name[o].type_str)
                return float(b)
            return 0.0

        def feeds_buffer(d: Instr, pname: str) -> bool:
            """Is param `pname` the in-place buffer operand (op 0) of DUS d,
            possibly through transparent ops?"""
            if not d.operands:
                return False
            o = d.operands[0]
            for _ in range(8):
                if o == pname:
                    return True
                nxt = by_name.get(o)
                if nxt is None or nxt.op not in TRANSPARENT or not nxt.operands:
                    return False
                o = nxt.operands[0]
            return False

        read = 0.0
        for pi in (i for i in comp if i.op == "parameter"):
            terms = terminals(pi.name)
            if terms and all(t.op == "dynamic-slice" for t in terms):
                read += sum(shape_bytes(t.type_str) for t in terms)
            elif terms and all(t.op == "dynamic-update-slice" and feeds_buffer(t, pi.name)
                               or t.op == "dynamic-update-slice"
                               for t in terms) and                     all(t.op == "dynamic-update-slice" for t in terms) and                     any(feeds_buffer(t, pi.name) or True for t in terms):
                # param flows (via converts) into DUS; if it is the updated
                # buffer, only the overwritten region is touched
                buf_like = [t for t in terms if shape_elems(t.type_str)
                            == shape_elems(pi.type_str)]
                if buf_like:
                    read += sum(upd_bytes(t) for t in buf_like)
                else:
                    read += shape_bytes(pi.type_str)
            else:
                read += shape_bytes(pi.type_str)

        write = float(shape_bytes(ins.type_str))
        result_e = shape_elems(ins.type_str)
        for d in comp:
            if d.op == "dynamic-update-slice" and shape_elems(d.type_str) == result_e:
                write = upd_bytes(d)
                break
        return read + write

    def _is_pure_upcast(self, ins: Instr) -> bool:
        """bf16 -> f32 convert-only fusions: XLA:CPU upcasts bf16 operands
        before every dot; the TPU MXU consumes bf16 natively, so this traffic
        does not exist on the target hardware.  Counted separately."""
        if ins.op != "fusion" or not ins.name.startswith(("convert", "wrapped_convert")):
            return False
        called = ins.attr("calls")
        comp = self.m.computations.get(called or "", [])
        real = [i for i in comp if i.op not in ("parameter", "bitcast", "copy", "transpose")]
        if not real or any(i.op not in ("convert",) for i in real):
            return False
        return "f32" in ins.type_str

    # -- main walk -------------------------------------------------------
    def computation(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Costs()  # cycle guard
        c = Costs()
        for ins in self.m.computations.get(name, []):
            op = ins.op
            if op in ("dot", "convolution"):
                f, b = self._dot_flops(ins), self._io_bytes(ins)
                c.flops += f
                c.bytes += b
                c.tag(ins.rest, f, b)
            elif op == "while":
                body = ins.attr("body")
                cond = ins.attr("condition")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    c.add(self.computation(body), trips)
                if cond:
                    c.add(self.computation(cond), trips)
            elif op == "fusion":
                called = ins.attr("calls")
                subf = 0.0
                if called:
                    sub = self.computation(called)
                    subf = sub.flops
                    c.flops += sub.flops           # dots inside fusions
                    for k, v in sub.coll.items():
                        c.coll[k] = c.coll.get(k, 0.0) + v
                b = self._io_bytes(ins)            # fusion io only
                if self._is_pure_upcast(ins):
                    c.cpu_upcast_bytes += b        # XLA:CPU artifact, see above
                else:
                    c.bytes += b
                    c.tag(ins.rest, subf, b)
            elif op in ("call", "async-start"):
                called = ins.attr("to") or ins.attr("calls")
                if called:
                    c.add(self.computation(called))
            elif op == "conditional":
                for key in ("true_computation", "false_computation"):
                    sub = ins.attr(key)
                    if sub:
                        c.add(self.computation(sub), 0.5)
                m = re.search(r"branch_computations={([^}]*)}", ins.rest)
                if m:
                    subs = _OPERAND_RE.findall(m.group(1))
                    for s in subs:
                        c.add(self.computation(s), 1.0 / max(len(subs), 1))
            elif any(op.startswith(k) for k in COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind = next(k for k in COLLECTIVES if op.startswith(k))
                b = sum(shape_bytes(self.m.types.get(o, "")) for o in ins.operands)
                c.coll[kind] = c.coll.get(kind, 0.0) + b * _WIRE_FACTOR[kind]
                c.bytes += self._io_bytes(ins)
            elif op in _SKIP_BYTES:
                continue
            else:  # unfused top-level elementwise / reduce / gather / dus ...
                b = self._io_bytes(ins)
                c.bytes += b
                c.tag(ins.rest, 0.0, b)
        self._memo[name] = c
        return c

    def entry_costs(self) -> Costs:
        c = self.computation(self.m.entry)
        c.coll["total"] = sum(v for k, v in c.coll.items() if k != "total")
        return c


def analyze_text(hlo_text: str) -> Costs:
    return Analyzer(parse(hlo_text)).entry_costs()
