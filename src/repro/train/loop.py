"""Training loop: resume-from-checkpoint, periodic async checkpoints,
SIGTERM/SIGINT preemption save, straggler-tolerant prefetch.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import Prefetcher, SyntheticSource, packed_batch
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.trainstep import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    microbatches: int = 1
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    seed: int = 0
    compress_grads: bool = False
    shard_id: int = 0
    num_shards: int = 1


def run(cfg: ModelConfig, opt_cfg: opt.OptimizerConfig, loop: LoopConfig,
        *, source=None, log: Callable[[str], None] = print) -> dict:
    """Train (or resume) a model; returns final metrics."""
    source = source or SyntheticSource(seed=loop.seed)
    step0 = 0
    resumed = ckpt.latest_step(loop.ckpt_dir)
    if resumed is not None:
        step0, trees = ckpt.load(loop.ckpt_dir)
        params, opt_state = trees["params"], trees["opt_state"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        log(f"[train] resumed from step {step0}")
    else:
        params = registry.init_params(cfg, jax.random.PRNGKey(loop.seed))
        opt_state = opt.init_state(params, opt_cfg)

    err_buf = None
    if loop.compress_grads:
        from repro.train.grad_compress import init_error_buffer
        err_buf = init_error_buffer(params)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatches=loop.microbatches,
                                      compress=loop.compress_grads))

    saver = ckpt.AsyncCheckpointer(loop.ckpt_dir, keep=loop.keep)
    preempted = {"flag": False}

    def handle(sig, frame):  # preemption: save and stop cleanly
        preempted["flag"] = True

    old_handlers = {s: signal.signal(s, handle) for s in (signal.SIGTERM, signal.SIGINT)}

    def make_batch(step: int) -> dict:
        return packed_batch(source, step, batch=loop.batch, seq_len=loop.seq_len,
                            shard_id=loop.shard_id, num_shards=loop.num_shards,
                            seed=loop.seed)

    pre = Prefetcher(make_batch).start(from_step=step0)
    metrics: dict[str, Any] = {}
    t0 = time.time()
    tokens_done = 0
    try:
        for step in range(step0, loop.steps):
            batch = pre.get(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if loop.compress_grads:
                params, opt_state, err_buf, metrics = step_fn(params, opt_state, batch, err_buf)
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            tokens_done += loop.batch * loop.seq_len
            if (step + 1) % loop.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                tps = tokens_done / max(time.time() - t0, 1e-9)
                log(f"[train] step {step+1} loss={m.get('loss', float('nan')):.4f} "
                    f"grad_norm={m.get('grad_norm', 0):.3f} lr={m.get('lr', 0):.2e} tok/s={tps:.0f}")
            if (step + 1) % loop.ckpt_every == 0 or step + 1 == loop.steps or preempted["flag"]:
                saver.save(step + 1, {"params": params, "opt_state": opt_state})
            if preempted["flag"]:
                log(f"[train] preemption signal — checkpointed at step {step+1}, exiting")
                break
    finally:
        pre.stop()
        saver.wait()
        for s, h in old_handlers.items():
            signal.signal(s, h)
    return {k: float(v) for k, v in metrics.items()} | {
        "last_step": step + 1 if loop.steps > step0 else step0,
        "stragglers": pre.stragglers,
    }
