"""Int8 gradient compression with error feedback (1-bit-Adam-family trick).

Applied at the data-parallel reduction boundary: gradients are quantized to
int8 with a per-tensor scale before crossing the slow (DCI / pod) links, and
the quantization residual is kept in an error-feedback buffer that is added
back into the next step's gradient — preserving convergence (the residuals
telescope).  On the GSPMD single-program path the quantize/dequantize pair
runs just before the optimizer (XLA keeps the int8 form across the reduce);
the shard_map pipeline/DP paths call ``compress`` explicitly around their
``psum``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_buffer(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, err):
    """Returns (int8 payload, scale, new_error_buffer, dequantized grad)."""
    x = g.astype(jnp.float32) + err
    q, scale = _quantize(x)
    deq = _dequantize(q, scale)
    return q, scale, x - deq, deq


def compress_tree(grads, err_buf):
    """Error-feedback int8 round-trip on every leaf.

    Returns (dequantized grads, new error buffers).  The int8 payload is what
    would cross the wire; the caller reduces either the payload (shard_map
    paths) or the dequantized value (GSPMD path).
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_buf)
    outs = [compress_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_err = jax.tree.unflatten(tdef, [o[2] for o in outs])
    deq = jax.tree.unflatten(tdef, [o[3] for o in outs])
    return deq, new_err
