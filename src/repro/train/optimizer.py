"""AdamW with f32 master weights, global-norm clipping and cosine schedule.

(optax is not available offline — this is a from-scratch implementation with
the same semantics; state is a plain pytree so it checkpoints/reshards like
params.)
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # memory knobs for very large models (e.g. 400B on a single 256-chip pod):
    state_dtype: str = "float32"     # dtype of m/v moments
    use_master: bool = True          # keep f32 master copy of params


def lr_at(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.learning_rate * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_state(params, cfg: OptimizerConfig | None = None) -> dict:
    sd = jnp.dtype(cfg.state_dtype) if cfg else jnp.float32
    mk = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, sd), t)
    state = {"step": jnp.zeros((), jnp.int32), "m": mk(params), "v": mk(params)}
    if cfg is None or cfg.use_master:
        state["master"] = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def apply_updates(cfg: OptimizerConfig, params, state, grads):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sd = jnp.dtype(cfg.state_dtype)
    has_master = "master" in state

    def upd(m, v, g, w):
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = mf / b1c
        vh = vf / b2c
        wf = w.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * wf
        return mf.astype(sd), vf.astype(sd), wf - lr * delta

    flat_m, tdef = jax.tree.flatten(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_g = jax.tree.leaves(grads)
    flat_w = jax.tree.leaves(state["master"] if has_master else params)
    out = [upd(m, v, g, w) for m, v, g, w in zip(flat_m, flat_v, flat_g, flat_w)]
    new_m = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_w = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), new_w, params)
    new_state = {"step": step, "m": new_m, "v": new_v}
    if has_master:
        new_state["master"] = new_w
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(param_specs_tree: dict, cfg: OptimizerConfig | None = None) -> dict:
    """SpecTree for optimizer state given model ParamSpecs (for dry-run)."""
    from repro.common import ParamSpec
    cfg = cfg or OptimizerConfig()
    sd = jnp.dtype(cfg.state_dtype)
    out = {("step",): ParamSpec((), (), dtype=jnp.int32, init="zeros")}
    names = ("m", "v") + (("master",) if cfg.use_master else ())
    for path, s in param_specs_tree.items():
        for name in names:
            dt = jnp.float32 if name == "master" else sd
            out[(name,) + path] = ParamSpec(s.shape, s.axes, dtype=dt, init="zeros")
    return out
