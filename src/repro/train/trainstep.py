"""Training step: CE loss + MoE aux, microbatch gradient accumulation via
scan (live activations bounded by one microbatch), optional int8
error-feedback compression, AdamW update.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.tokenizer import TOKENIZER
from repro.models import registry
from repro.train import grad_compress, optimizer as opt


def loss_fn(cfg: ModelConfig, params, tokens, labels, extra=None):
    """Causal-LM cross-entropy, ignoring PAD labels; adds MoE aux losses."""
    logits, aux = registry.forward(cfg, params, tokens, extra=extra, remat=cfg.remat)
    valid = (labels != TOKENIZER.pad_id) & (labels >= 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logp, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    ce = -jnp.sum(jnp.where(valid, tgt, 0.0)) / denom
    total = ce
    for v in (aux or {}).values():
        total = total + v
    return total, {"ce": ce, **{k: v for k, v in (aux or {}).items()}}


def make_train_step(cfg: ModelConfig, opt_cfg: opt.OptimizerConfig, *,
                    microbatches: int = 1, compress: bool = False):
    """Returns train_step(params, opt_state, batch[, err_buf]) -> (...)"""

    grad_fn = jax.value_and_grad(functools.partial(loss_fn, cfg), has_aux=True)

    def accumulate(params, tokens, labels, extra):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels, extra)
            return loss, metrics, grads

        b = tokens.shape[0]
        assert b % microbatches == 0, (b, microbatches)
        mb = b // microbatches
        resh = lambda t: t.reshape((microbatches, mb) + t.shape[1:])
        tokens_mb, labels_mb = resh(tokens), resh(labels)
        extra_mb = jax.tree.map(resh, extra) if extra else None
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, xs):
            gacc, lacc = carry
            if extra_mb is not None:
                t, l, e = xs
            else:
                (t, l), e = xs, None
            (loss, metrics), grads = grad_fn(params, t, l, e)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (gacc, lacc + loss), metrics

        xs = (tokens_mb, labels_mb, extra_mb) if extra_mb is not None else (tokens_mb, labels_mb)
        (gacc, lsum), metrics = jax.lax.scan(body, (zeros, 0.0), xs)
        grads = jax.tree.map(lambda g: g / microbatches, gacc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return lsum / microbatches, metrics, grads

    def train_step(params, opt_state, batch, err_buf=None):
        extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")} or None
        loss, metrics, grads = accumulate(params, batch["tokens"], batch["labels"], extra)
        if compress:
            grads, err_buf = grad_compress.compress_tree(grads, err_buf)
        params, opt_state, om = opt.apply_updates(opt_cfg, params, opt_state, grads)
        metrics = {"loss": loss, **metrics, **om}
        if compress:
            return params, opt_state, err_buf, metrics
        return params, opt_state, metrics

    return train_step
