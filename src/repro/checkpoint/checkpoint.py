"""Checkpointing: atomic, async, mesh-agnostic (elastic restart).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir and
renamed (atomic on POSIX).  Arrays are saved *logically* (unsharded host
arrays keyed by pytree path) with their logical axis names in the manifest,
so a restart may use a different mesh shape / pod count: ``restore_sharded``
re-resolves shardings against the new mesh (elastic scaling).  On a real
multi-host cluster each process would save only its addressable shards with
the same manifest format; the single-controller path here saves full arrays.

``AsyncCheckpointer`` snapshots to host memory synchronously (one device_get)
and does the disk I/O on a background thread — the train loop continues while
bytes hit disk; ``wait()`` surfaces any background error.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.common import flatten, unflatten

_SEP = "|"

# numpy can't round-trip ml_dtypes (bfloat16 etc.) through npz; store raw bits.
_BIT_VIEW = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _flat_np(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    flat = flatten(tree)
    arrays: dict[str, np.ndarray] = {}
    dtypes: dict[str, str] = {}
    for path, v in flat.items():
        key = _SEP.join(path)
        a = np.asarray(v)
        dtypes[key] = a.dtype.name
        if a.dtype.kind not in "biufc":  # ml_dtypes -> raw bit view
            a = a.view(_BIT_VIEW[a.dtype.itemsize])
        arrays[key] = a
    return arrays, dtypes


def _restore_dtype(a: np.ndarray, name: str) -> np.ndarray:
    if a.dtype.name == name:
        return a
    import ml_dtypes
    return a.view(np.dtype(getattr(ml_dtypes, name)))


def save(ckpt_dir: str, step: int, trees: dict[str, Any], *, keep: int = 3,
         extra_meta: dict | None = None) -> str:
    """trees: {"params": ..., "opt_state": ..., ...} (each a pytree)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: dict[str, Any] = {"step": step, "trees": {}, "dtypes": {}, "time": time.time(),
                                "meta": extra_meta or {}}
    for name, tree in trees.items():
        arrays, dtypes = _flat_np(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
        manifest["trees"][name] = sorted(arrays.keys())
        manifest["dtypes"][name] = dtypes
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load(ckpt_dir: str, step: int | None = None) -> tuple[int, dict[str, Any]]:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out: dict[str, Any] = {}
    for name in manifest["trees"]:
        dtypes = manifest.get("dtypes", {}).get(name, {})
        with np.load(os.path.join(d, f"{name}.npz")) as z:
            flat = {tuple(k.split(_SEP)): _restore_dtype(z[k], dtypes.get(k, z[k].dtype.name))
                    for k in z.files}
        out[name] = unflatten(flat)
    return step, out


def restore_sharded(ckpt_dir: str, shardings: dict[str, Any], step: int | None = None):
    """Elastic restore: place saved arrays with *new-mesh* shardings.

    ``shardings``: {"params": tree of NamedSharding, ...} resolved against the
    current mesh (see repro.dist.sharding.spec_shardings) — the saved mesh
    shape is irrelevant, which is what makes restart-on-a-different-topology
    (scale up/down, lost pod) work.
    """
    step, trees = load(ckpt_dir, step)
    out = {}
    for name, tree in trees.items():
        if name in shardings:
            out[name] = jax.tree.map(jax.device_put, tree, shardings[name])
        else:
            out[name] = tree
    return step, out


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, trees: dict[str, Any], extra_meta: dict | None = None) -> None:
        self.wait()
        host_trees = {n: jax.tree.map(np.asarray, t) for n, t in trees.items()}  # snapshot

        def work():
            try:
                save(self.ckpt_dir, step, host_trees, keep=self.keep, extra_meta=extra_meta)
            except BaseException as e:  # noqa: BLE001 - surfaced via wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
